//! Property-based tests on coordinator invariants (proptest is not
//! vendored offline; properties are driven by the in-repo xoshiro RNG
//! with fixed seeds — failures are reproducible by construction).

use pahq::gpu_sim::{CostModel, Sim, StreamId};
use pahq::metrics::{auc_pessimistic, confusion, RocPoint};
use pahq::model::{Channel, Graph};
use pahq::patching::PatchMask;
use pahq::quant::{self, Format};
use pahq::tensor::{
    accumulate_quantized_packed, add_assign, add_assign_packed, add_sub_assign,
    add_sub_assign_packed, add_sub_assign_packed_rev, QTensor,
};
use pahq::util::json::Json;
use pahq::util::rng::Rng;

const PACKED_FORMATS: [Format; 5] = [
    quant::FP16,
    quant::BF16,
    quant::FP8_E4M3,
    quant::FP8_E5M2,
    quant::FP4_E2M1,
];

fn random_graph(rng: &mut Rng) -> Graph {
    Graph {
        n_layer: 1 + rng.below(6),
        n_head: 1 + rng.below(12),
        has_mlp: rng.below(2) == 1,
    }
}

#[test]
fn graph_sources_are_causal_and_complete() {
    // For every random graph: every edge's source strictly precedes its
    // destination's compute point, sources are sorted & unique, and the
    // edge set is exactly the union over channels of their sources.
    let mut rng = Rng::new(101);
    for _ in 0..40 {
        let g = random_graph(&mut rng);
        let mut counted = 0usize;
        for ch in g.channels() {
            let srcs = g.sources(ch);
            counted += srcs.len();
            let mut sorted = srcs.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), srcs.len(), "unique");
            for &s in &srcs {
                assert!(s < g.n_nodes());
                // destination channel of layer l never reads a node of a
                // later layer
                if let Channel::Head { layer, .. } = ch {
                    match g.node_kind(s) {
                        pahq::model::graph::NodeKind::Head { layer: sl, .. } => {
                            assert!(sl < layer)
                        }
                        pahq::model::graph::NodeKind::Mlp { layer: sl } => assert!(sl < layer),
                        pahq::model::graph::NodeKind::Embed => {}
                    }
                }
            }
        }
        assert_eq!(counted, g.n_edges());
    }
}

#[test]
fn patch_mask_set_get_roundtrip() {
    let mut rng = Rng::new(202);
    for _ in 0..30 {
        let g = random_graph(&mut rng);
        let channels = g.channels();
        let mut mask = PatchMask::empty(channels.len());
        let edges = g.edges();
        // random subset in, then out
        let mut on = Vec::new();
        for e in &edges {
            if rng.below(3) == 0 {
                let ci = channels.iter().position(|c| *c == e.dst).unwrap();
                mask.set(ci, e.src, true);
                on.push((ci, e.src));
            }
        }
        assert_eq!(mask.count(), {
            let mut d = on.clone();
            d.sort_unstable();
            d.dedup();
            d.len()
        });
        for &(ci, src) in &on {
            assert!(mask.get(ci, src));
            mask.set(ci, src, false);
        }
        assert_eq!(mask.count(), 0);
    }
}

#[test]
fn fq_is_projection_and_monotone_everywhere() {
    // randomized sweep across formats and magnitudes: idempotent,
    // monotone, symmetric, bounded
    let mut rng = Rng::new(303);
    let formats = [
        quant::FP8_E4M3,
        quant::FP8_E5M2,
        quant::FP4_E2M1,
        quant::BF16,
        quant::FP16,
    ];
    for f in formats {
        let mut xs: Vec<f32> = (0..4000)
            .map(|_| {
                let e = rng.f32() * 60.0 - 30.0;
                let sign = if rng.below(2) == 0 { -1.0 } else { 1.0 };
                sign * e.exp2() * (1.0 + rng.f32())
            })
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let ys: Vec<f32> = xs.iter().map(|&x| quant::fq(x, f)).collect();
        for w in ys.windows(2) {
            assert!(w[0] <= w[1], "monotone {f:?}");
        }
        for (&x, &y) in xs.iter().zip(&ys) {
            assert_eq!(quant::fq(y, f), y, "idempotent");
            assert!(y.abs() <= f.maxv, "bounded");
            assert_eq!(quant::fq(-x, f), -y, "odd symmetry");
        }
    }
}

#[test]
fn quantized_accumulation_never_beats_fp32_precision() {
    // summing n positive values: the quantized running sum is always
    // within the final clamp, and coarser formats lose at least as much
    // mass as finer ones (monotonicity of mantissa loss in mbits)
    let mut rng = Rng::new(404);
    for _ in 0..50 {
        let n = 5 + rng.below(60);
        let xs: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0).collect();
        let exact: f32 = xs.iter().sum();
        let mut err_by_fmt = Vec::new();
        for f in [quant::FP16, quant::FP8_E4M3, quant::FP4_E2M1] {
            let mut acc = vec![0.0f32];
            for &x in &xs {
                quant::accumulate_quantized(&mut acc, &[x], f);
            }
            err_by_fmt.push((acc[0] - exact).abs());
        }
        assert!(
            err_by_fmt[0] <= err_by_fmt[2] + 1e-3 * exact.abs(),
            "fp16 err {} <= fp4 err {} (exact {exact})",
            err_by_fmt[0],
            err_by_fmt[2]
        );
    }
}

#[test]
fn auc_respects_dominance_under_random_point_sets() {
    let mut rng = Rng::new(505);
    for _ in 0..50 {
        let n = 1 + rng.below(20);
        let pts: Vec<RocPoint> = (0..n)
            .map(|_| RocPoint { fpr: rng.f64(), tpr: rng.f64() })
            .collect();
        let auc = auc_pessimistic(&pts);
        assert!((0.0..=1.0).contains(&auc));
        // shifting every point up (tpr+δ clamped) never lowers AUC
        let better: Vec<RocPoint> = pts
            .iter()
            .map(|p| RocPoint { fpr: p.fpr, tpr: (p.tpr + 0.2).min(1.0) })
            .collect();
        assert!(auc_pessimistic(&better) >= auc - 1e-12);
    }
}

#[test]
fn confusion_matches_hand_counts_on_random_vectors() {
    let mut rng = Rng::new(606);
    for _ in 0..50 {
        let n = 1 + rng.below(200);
        let pred: Vec<bool> = (0..n).map(|_| rng.below(2) == 1).collect();
        let truth: Vec<bool> = (0..n).map(|_| rng.below(2) == 1).collect();
        let p = confusion(&pred, &truth);
        let tp = pred.iter().zip(&truth).filter(|(&a, &b)| a && b).count() as f64;
        let fp = pred.iter().zip(&truth).filter(|(&a, &b)| a && !b).count() as f64;
        let pos = truth.iter().filter(|&&t| t).count() as f64;
        let neg = n as f64 - pos;
        if pos > 0.0 {
            assert!((p.tpr - tp / pos).abs() < 1e-12);
        }
        if neg > 0.0 {
            assert!((p.fpr - fp / neg).abs() < 1e-12);
        }
    }
}

#[test]
fn des_makespan_bounds() {
    // makespan >= busiest stream; adding an op never decreases makespan;
    // makespan <= sum of all durations (work conservation bounds)
    let mut rng = Rng::new(707);
    for _ in 0..40 {
        let mut sim = Sim::new(3);
        let mut total = 0.0;
        let mut prev_span = 0.0;
        let mut events = Vec::new();
        for _ in 0..60 {
            let s = StreamId(rng.below(3));
            let d = rng.f64() * 20.0;
            total += d;
            let deps: Vec<_> = (0..rng.below(3).min(events.len()))
                .map(|_| events[rng.below(events.len())])
                .collect();
            let e = sim.op(s, d, &deps, "op");
            events.push(e);
            let span = sim.makespan();
            assert!(span >= prev_span, "monotone");
            prev_span = span;
        }
        let busiest = (0..3)
            .map(|s| sim.utilization(StreamId(s)) * sim.makespan())
            .fold(0.0f64, f64::max);
        assert!(sim.makespan() >= busiest - 1e-9);
        assert!(sim.makespan() <= total + 1e-9);
    }
}

#[test]
fn cost_model_monotone_in_every_argument() {
    let c = CostModel::default();
    let mut rng = Rng::new(808);
    for _ in 0..60 {
        let (m, n, k) = (1 + rng.below(4096), 1 + rng.below(4096), 1 + rng.below(4096));
        let f = quant::FP8_E4M3;
        assert!(c.gemm_us(m + 64, n, k, f) >= c.gemm_us(m, n, k, f));
        assert!(c.gemm_us(m, n + 64, k, f) >= c.gemm_us(m, n, k, f));
        let b = rng.below(1 << 24);
        assert!(c.transfer_us(b + 4096, 1) >= c.transfer_us(b, 1));
        assert!(c.transfer_us(b, 10) >= c.transfer_us(b, 1));
        assert!(c.elementwise_us(b + 4096) >= c.elementwise_us(b));
    }
}

#[test]
fn json_fuzz_roundtrip() {
    // random JSON trees survive dump -> parse exactly
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.below(1 << 20) as f64) - (1 << 19) as f64),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| {
                        let opts = ['a', 'Z', '"', '\\', '\n', 'ü', '7', ' '];
                        opts[rng.below(opts.len())]
                    })
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(909);
    for _ in 0..200 {
        let v = gen(&mut rng, 3);
        let back = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, back);
    }
}

#[test]
fn batched_sweep_is_bit_identical_to_serial() {
    // The sweep engine's core contract: across seeded random graphs,
    // policies (hi-override on/off), and thresholds, the batched
    // speculative sweep returns exactly the serial sweep's kept set,
    // kept count, and final metric — bit for bit.
    use pahq::acdc::sweep::{self, Candidate, FnScorer, SweepMode, SyntheticSurface};
    let mut rng = Rng::new(1010);
    for round in 0..12u64 {
        let g = random_graph(&mut rng);
        let channels = g.channels();
        // plan mirrors acdc::sweep_plan: reverse-topological channels,
        // reversed sources within each channel
        let pahq_like = round % 2 == 0;
        let mut order = channels.clone();
        order.reverse();
        let mut plan: Vec<Vec<Candidate>> = Vec::new();
        for ch in order {
            let ci = channels.iter().position(|c| *c == ch).unwrap();
            let mut srcs = g.sources(ch);
            srcs.reverse();
            plan.push(
                srcs.into_iter()
                    .map(|src| Candidate {
                        chan: ci,
                        src,
                        hi: if pahq_like { Some(src) } else { None },
                    })
                    .collect(),
            );
        }
        let surface = SyntheticSurface::new(2000 + round, 0.01);
        let tau = [0.05f32, 0.3, 0.6, 0.95][rng.below(4)];
        let score = |m: &PatchMask, c: Option<&Candidate>| surface.damage(m, c);
        let run = |mode: SweepMode, workers: usize| {
            let mut scorer = FnScorer { score, workers };
            sweep::sweep(&mut scorer, channels.len(), &plan, tau, true, mode).unwrap()
        };
        let kept = |out: &sweep::SweepOutcome| -> Vec<bool> {
            g.edges()
                .iter()
                .map(|e| {
                    let ci = channels.iter().position(|c| *c == e.dst).unwrap();
                    !out.removed.get(ci, e.src)
                })
                .collect()
        };
        let serial = run(SweepMode::Serial, 1);
        for workers in [2usize, 3, 8] {
            let batched = run(SweepMode::Batched { workers }, workers);
            assert_eq!(
                kept(&serial),
                kept(&batched),
                "kept set (round {round}, workers {workers}, tau {tau})"
            );
            assert_eq!(serial.removed_count, batched.removed_count, "kept count");
            assert_eq!(
                serial.final_metric.to_bits(),
                batched.final_metric.to_bits(),
                "final metric bits (round {round}, workers {workers})"
            );
            assert_eq!(serial.trace.len(), batched.trace.len(), "one decision per edge");
            for (a, b) in serial.trace.iter().zip(&batched.trace) {
                assert_eq!(a.removed, b.removed);
                assert_eq!(a.edges_remaining, b.edges_remaining);
                assert_eq!(a.metric.to_bits(), b.metric.to_bits());
            }
        }
    }
}

#[test]
fn qtensor_pack_unpack_bit_identical_to_fq() {
    // For every packed format: decode(encode(x)) must equal fq(x) BIT FOR
    // BIT over ±0, f32 subnormals (FTZ region), format subnormals, the
    // emin boundary, saturation bounds, ties-to-even cases at several
    // binades, and a seeded random magnitude sweep.
    let mut rng = Rng::new(2024);
    for f in PACKED_FORMATS {
        let m = f.mbits as i32;
        let emin = f.emin as i32;
        let emax = ((f.maxv.to_bits() >> 23) as i32) - 127;
        let mut xs: Vec<f32> = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            1e-45, // smallest f32 subnormal: flushed to zero
            -1e-42,
            1e-38, // still below MIN_POSITIVE: flushed
            f.maxv,
            -f.maxv,
            f.maxv * 0.999,
            f.maxv * 2.0, // saturates
            f32::MAX,
            -f32::MAX,
            f32::INFINITY, // clamps to maxv
            f32::NEG_INFINITY,
            2f32.powi(emin), // smallest normal
            -(2f32.powi(emin)),
            2f32.powi(emin) * 1.5,
            2f32.powi(emin - m),     // smallest format subnormal
            2f32.powi(emin - m - 1), // rounds: below half the quantum
            2f32.powi(emin - m) * 0.75,
            2f32.powi(emax),
        ];
        // ties-to-even: x = (j + 0.5) * 2^(e - m) sits exactly between
        // lattice neighbours j and j+1 (even j rounds down, odd rounds up)
        for e in [emin, (emin + emax) / 2, emax] {
            let scale = 2f32.powi(e - m);
            for j in [1 << m, (1 << m) + 1, (2 << m) - 2, (2 << m) - 1] {
                xs.push((j as f32 + 0.5) * scale);
                xs.push(-((j as f32 + 0.5) * scale));
            }
        }
        // random sweep over ~the full exponent range
        for _ in 0..4000 {
            let e = rng.f32() * 300.0 - 150.0;
            let sign = if rng.below(2) == 0 { -1.0 } else { 1.0 };
            xs.push(sign * e.exp2() * (1.0 + rng.f32()));
        }
        let qt = QTensor::from_slice(&[xs.len()], &xs, f);
        assert_eq!(qt.bytes(), f.bytes_for(xs.len()), "native payload width {f:?}");
        let mut dec = vec![0.0f32; xs.len()];
        qt.decode_into(&mut dec);
        for (i, (&x, &y)) in xs.iter().zip(&dec).enumerate() {
            let want = quant::fq(x, f);
            assert_eq!(
                y.to_bits(),
                want.to_bits(),
                "{f:?}[{i}]: decode(encode({x:e})) = {y:e}, fq = {want:e}"
            );
        }
        // element access agrees with bulk decode
        for i in (0..xs.len()).step_by(97) {
            assert_eq!(qt.get(i).to_bits(), dec[i].to_bits());
        }
    }
}

#[test]
fn packed_kernels_bitwise_match_plain_ops() {
    // The fused packed kernels must produce exactly the floats the old
    // "decode whole tensor, then f32 op" path produced — on every format
    // (including the f32 passthrough payload) and on odd lengths that
    // exercise the fp4 nibble tail.
    let mut rng = Rng::new(515);
    for f in [quant::FP32, quant::BF16, quant::FP8_E4M3, quant::FP4_E2M1] {
        for n in [1usize, 2, 7, 64, 255] {
            let raw: Vec<f32> = (0..n).map(|_| rng.normal() * 8.0).collect();
            let other: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let qt = QTensor::from_slice(&[n], &raw, f);
            let mut dec = vec![0.0f32; n];
            qt.decode_into(&mut dec);

            let mut a = base.clone();
            add_assign_packed(&mut a, &qt);
            let mut aw = base.clone();
            add_assign(&mut aw, &dec);
            assert_eq!(a, aw, "add_assign_packed {f:?} n={n}");

            let mut b = base.clone();
            add_sub_assign_packed(&mut b, &qt, &other);
            let mut bw = base.clone();
            add_sub_assign(&mut bw, &dec, &other);
            assert_eq!(b, bw, "add_sub_assign_packed {f:?} n={n}");

            let mut c = base.clone();
            add_sub_assign_packed_rev(&mut c, &other, &qt);
            let mut cw = base.clone();
            add_sub_assign(&mut cw, &other, &dec);
            assert_eq!(c, cw, "add_sub_assign_packed_rev {f:?} n={n}");

            let mut d = base.clone();
            accumulate_quantized_packed(&mut d, &qt, quant::FP8_E4M3);
            let mut dw = base.clone();
            quant::accumulate_quantized(&mut dw, &dec, quant::FP8_E4M3);
            assert_eq!(d, dw, "accumulate_quantized_packed {f:?} n={n}");
        }
    }
}

#[test]
fn word_parallel_decode_matches_scalar_on_word_boundaries() {
    // The PR 7 word-parallel decoders vs the retained scalar oracle
    // (`decode_range_into_scalar`): every packed format, adversarial
    // lengths around the 64-bit word size (8 fp8 lanes / 16 fp4 nibbles
    // / 4 u16 lanes per word), unaligned range starts that straddle a
    // word — including odd nibble offsets — and special values (±0,
    // Inf saturating to maxv, the format-subnormal ladder, flushed f32
    // subnormals) packed into the same word.
    let mut rng = Rng::new(818);
    let lens = [0usize, 1, 7, 8, 9, 15, 16, 17, 31, 64, 79];
    let starts = [0usize, 1, 3, 5, 7, 8, 9, 15, 16, 17];
    for f in PACKED_FORMATS {
        let m = f.mbits as i32;
        let emin = f.emin as i32;
        let mut raw: Vec<f32> = vec![
            0.0,
            -0.0,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f.maxv,
            -f.maxv,
            2f32.powi(emin),         // smallest normal
            2f32.powi(emin - m),     // smallest format subnormal
            -(2f32.powi(emin - m)),
            2f32.powi(emin - m - 1), // below half the quantum: rounds to zero
            1e-45, // f32 subnormal: flushed to zero before encode
            -1e-42,
            1.0,
            -1.0,
        ];
        while raw.len() < 96 {
            raw.push(rng.normal() * 8.0);
        }
        let qt = QTensor::from_slice(&[raw.len()], &raw, f);
        for &n in &lens {
            for &start in &starts {
                if start + n > raw.len() {
                    continue;
                }
                let mut wide = vec![f32::NAN; n];
                let mut scalar = vec![f32::NAN; n];
                qt.decode_range_into(start, &mut wide);
                qt.decode_range_into_scalar(start, &mut scalar);
                for i in 0..n {
                    assert_eq!(
                        wide[i].to_bits(),
                        scalar[i].to_bits(),
                        "{f:?} start={start} n={n} [{i}]: wide {} vs scalar {}",
                        wide[i],
                        scalar[i]
                    );
                }
            }
        }
    }
    // NaN lanes ride the f32 passthrough payload only: the packed
    // formats have no NaN encoding (Inf/NaN-free by construction — fq
    // saturates Inf and rejects NaN), so passthrough is where NaN bit
    // patterns must survive the word loop untouched.
    let raw = [f32::NAN, -0.0, f32::INFINITY, -f32::NAN, 1e-45, 2.5, f32::NEG_INFINITY, 0.0];
    let qt = QTensor::from_slice(&[raw.len()], &raw, quant::FP32);
    for start in 0..raw.len() {
        let n = raw.len() - start;
        let mut wide = vec![0.0f32; n];
        let mut scalar = vec![0.0f32; n];
        qt.decode_range_into(start, &mut wide);
        qt.decode_range_into_scalar(start, &mut scalar);
        for i in 0..n {
            assert_eq!(wide[i].to_bits(), scalar[i].to_bits(), "fp32 passthrough [{start}+{i}]");
        }
    }
}

#[test]
fn fused_kernels_match_scalar_composition_on_word_boundaries() {
    // Each fused word-parallel kernel vs its scalar-oracle composition
    // (`decode_range_into_scalar` + the plain f32 op) at lengths that
    // exercise empty inputs, the unrolled word body, and every
    // head/tail combination — bitwise, for every packed format plus
    // the f32 passthrough.
    let mut rng = Rng::new(919);
    let mut formats = PACKED_FORMATS.to_vec();
    formats.push(quant::FP32);
    for f in formats {
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 255, 256, 257] {
            let raw: Vec<f32> = (0..n).map(|_| rng.normal() * 8.0).collect();
            let other: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let qt = QTensor::from_slice(&[n], &raw, f);
            let mut dec = vec![0.0f32; n];
            qt.decode_range_into_scalar(0, &mut dec);

            let mut a = base.clone();
            add_assign_packed(&mut a, &qt);
            let mut aw = base.clone();
            add_assign(&mut aw, &dec);
            let mut b = base.clone();
            add_sub_assign_packed(&mut b, &qt, &other);
            let mut bw = base.clone();
            add_sub_assign(&mut bw, &dec, &other);
            let mut c = base.clone();
            add_sub_assign_packed_rev(&mut c, &other, &qt);
            let mut cw = base.clone();
            add_sub_assign(&mut cw, &other, &dec);
            let mut d = base.clone();
            accumulate_quantized_packed(&mut d, &qt, quant::FP8_E4M3);
            let mut dw = base.clone();
            quant::accumulate_quantized(&mut dw, &dec, quant::FP8_E4M3);
            for i in 0..n {
                assert_eq!(a[i].to_bits(), aw[i].to_bits(), "add_assign_packed {f:?} n={n} [{i}]");
                assert_eq!(
                    b[i].to_bits(),
                    bw[i].to_bits(),
                    "add_sub_assign_packed {f:?} n={n} [{i}]"
                );
                assert_eq!(
                    c[i].to_bits(),
                    cw[i].to_bits(),
                    "add_sub_assign_packed_rev {f:?} n={n} [{i}]"
                );
                assert_eq!(
                    d[i].to_bits(),
                    dw[i].to_bits(),
                    "accumulate_quantized_packed {f:?} n={n} [{i}]"
                );
            }
        }
    }
}

#[test]
fn packed_corrupt_cache_keeps_sweep_bit_identity() {
    // The tentpole invariant at the sweep level: running the greedy sweep
    // over a damage surface assembled from a PACKED corrupt cache gives
    // (a) bit-identical results to the same surface assembled from the
    // decoded f32 cache, and (b) bit-identical serial vs batched
    // outcomes — the two guarantees compose.
    use pahq::acdc::sweep::{self, Candidate, FnScorer, SweepMode, SweepOutcome};

    fn run_sweep<F>(
        score: F,
        n_channels: usize,
        plan: &[Vec<Candidate>],
        tau: f32,
        mode: SweepMode,
        workers: usize,
    ) -> SweepOutcome
    where
        F: Fn(&PatchMask, Option<&Candidate>) -> f32 + Sync,
    {
        let mut scorer = FnScorer { score, workers };
        sweep::sweep(&mut scorer, n_channels, plan, tau, true, mode).unwrap()
    }

    fn assert_same(a: &SweepOutcome, b: &SweepOutcome, what: &str) {
        assert_eq!(a.removed, b.removed, "{what}: removed mask");
        assert_eq!(a.removed_count, b.removed_count, "{what}: removed count");
        assert_eq!(
            a.final_metric.to_bits(),
            b.final_metric.to_bits(),
            "{what}: final metric bits"
        );
        assert_eq!(a.trace.len(), b.trace.len(), "{what}: trace length");
        for (x, y) in a.trace.iter().zip(&b.trace) {
            assert_eq!(x.removed, y.removed, "{what}: decision");
            assert_eq!(x.metric.to_bits(), y.metric.to_bits(), "{what}: metric bits");
        }
    }

    let mut rng = Rng::new(777);
    for round in 0..6u64 {
        let g = random_graph(&mut rng);
        let channels = g.channels();
        let n_nodes = g.n_nodes();
        let dim = 24usize;
        let clean: Vec<Vec<f32>> = (0..n_nodes)
            .map(|_| (0..dim).map(|_| rng.normal()).collect())
            .collect();
        let corrupt_raw: Vec<Vec<f32>> = (0..n_nodes)
            .map(|_| (0..dim).map(|_| rng.normal() * 2.0).collect())
            .collect();
        let fmt = [quant::FP8_E4M3, quant::BF16, quant::FP4_E2M1][rng.below(3)];
        let packed: Vec<QTensor> = corrupt_raw
            .iter()
            .map(|v| QTensor::from_slice(&[dim], v, fmt))
            .collect();
        let decoded: Vec<Vec<f32>> = packed
            .iter()
            .map(|q| {
                let mut o = vec![0.0f32; dim];
                q.decode_into(&mut o);
                o
            })
            .collect();
        let probe: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();

        // mini residual assembly: per channel, clean base + patch swaps
        // (packed or plain), scored by a fixed probe vector
        let assemble_damage = |mask: &PatchMask, cand: Option<&Candidate>, use_packed: bool| {
            let mut total = 0.0f32;
            for (ci, ch) in channels.iter().enumerate() {
                let srcs = g.sources(*ch);
                let mut bits = mask.mask(ci);
                if let Some(c) = cand {
                    if c.chan == ci {
                        bits |= 1u128 << c.src;
                    }
                }
                let mut acc = vec![0.0f32; dim];
                for &s in &srcs {
                    add_assign(&mut acc, &clean[s]);
                }
                for &s in &srcs {
                    if bits >> s & 1 == 1 {
                        if use_packed {
                            add_sub_assign_packed(&mut acc, &packed[s], &clean[s]);
                        } else {
                            add_sub_assign(&mut acc, &decoded[s], &clean[s]);
                        }
                    }
                }
                total += pahq::tensor::dot(&acc, &probe);
            }
            total
        };

        // plan mirrors acdc::sweep_plan: reverse-topological channels,
        // reversed sources within each channel
        let mut order = channels.clone();
        order.reverse();
        let mut plan: Vec<Vec<Candidate>> = Vec::new();
        for ch in order {
            let ci = channels.iter().position(|c| *c == ch).unwrap();
            let mut srcs = g.sources(ch);
            srcs.reverse();
            plan.push(srcs.into_iter().map(|src| Candidate { chan: ci, src, hi: None }).collect());
        }
        let tau = [0.0f32, 0.2, 1.0][rng.below(3)];

        let serial_packed = run_sweep(
            |m: &PatchMask, c: Option<&Candidate>| assemble_damage(m, c, true),
            channels.len(),
            &plan,
            tau,
            SweepMode::Serial,
            1,
        );
        let serial_plain = run_sweep(
            |m: &PatchMask, c: Option<&Candidate>| assemble_damage(m, c, false),
            channels.len(),
            &plan,
            tau,
            SweepMode::Serial,
            1,
        );
        assert_same(&serial_packed, &serial_plain, &format!("round {round}: packed vs plain"));
        for workers in [2usize, 4] {
            let batched = run_sweep(
                |m: &PatchMask, c: Option<&Candidate>| assemble_damage(m, c, true),
                channels.len(),
                &plan,
                tau,
                SweepMode::Batched { workers },
                workers,
            );
            assert_same(
                &serial_packed,
                &batched,
                &format!("round {round}: serial vs batched[{workers}]"),
            );
        }
    }
}

#[test]
fn qtensor_wire_codec_round_trips_bit_identically() {
    // The durable store's QTensor codec: from_bytes(to_bytes(q)) must
    // reproduce the packed payload verbatim — every format (including
    // the f32 passthrough), odd lengths exercising the fp4 nibble tail,
    // and multi-dim shapes — with no re-quantization round trip.
    let mut rng = Rng::new(616);
    let mut formats = PACKED_FORMATS.to_vec();
    formats.push(quant::FP32);
    for f in formats {
        for n in [1usize, 2, 7, 63, 255, 1024] {
            let mut raw: Vec<f32> = (0..n).map(|_| rng.normal() * 8.0).collect();
            raw[0] = 0.0;
            if n > 1 {
                raw[1] = -0.0;
            }
            let shape: Vec<usize> = if n % 2 == 0 { vec![2, n / 2] } else { vec![n] };
            let qt = QTensor::from_slice(&shape, &raw, f);
            let wire = qt.to_bytes();
            let back = QTensor::from_bytes(&wire).unwrap();
            assert_eq!(back.to_bytes(), wire, "{f:?} n={n}: wire fixed point");
            assert_eq!(back.bytes(), qt.bytes(), "{f:?} n={n}: payload width");
            let (mut a, mut b) = (vec![0.0f32; n], vec![0.0f32; n]);
            qt.decode_into(&mut a);
            back.decode_into(&mut b);
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{f:?} n={n} [{i}]: decoded bits");
            }
        }
    }
    // structural damage errors (never panics): the store quarantines
    let qt = QTensor::from_slice(&[5], &[1.0, -2.0, 3.0, -4.0, 5.0], quant::FP4_E2M1);
    let wire = qt.to_bytes();
    assert!(QTensor::from_bytes(&wire[..wire.len() - 1]).is_err(), "truncated payload");
    assert!(QTensor::from_bytes(&wire[..3]).is_err(), "truncated header");
    let mut trailing = wire.clone();
    trailing.push(0);
    assert!(QTensor::from_bytes(&trailing).is_err(), "trailing bytes");
    let mut bad_tag = wire.clone();
    bad_tag[0] = 9;
    assert!(QTensor::from_bytes(&bad_tag).is_err(), "unknown payload tag");
}

#[test]
fn artifact_value_codecs_round_trip_bit_identically() {
    // The typed store codecs (scores / corrupt caches / datasets) carry
    // f32 as raw bits — decode(encode(x)) is exact even for NaN,
    // infinities, signed zero, and subnormals — and reject truncation
    // and trailing garbage instead of mis-decoding.
    use pahq::matrix::cache::{
        decode_corrupt, decode_examples, decode_scores, encode_corrupt, encode_examples,
        encode_scores,
    };
    use pahq::model::Example;

    let mut rng = Rng::new(717);

    // score vectors, including the pathological f32s
    let mut scores: Vec<f32> = (0..257).map(|_| rng.normal() * 100.0).collect();
    scores.extend([0.0, -0.0, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 1e-45]);
    let enc = encode_scores(&scores);
    let dec = decode_scores(&enc).unwrap();
    assert_eq!(dec.len(), scores.len());
    for (i, (x, y)) in scores.iter().zip(&dec).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "scores[{i}]");
    }
    assert_eq!(decode_scores(&encode_scores(&[])).unwrap(), Vec::<f32>::new());
    assert!(decode_scores(&enc[..enc.len() - 1]).is_err(), "truncated scores");
    let mut trailing = enc.clone();
    trailing.push(0x7f);
    assert!(decode_scores(&trailing).is_err(), "trailing garbage");

    // corrupt caches: mixed-format plane lists round-trip per-plane bytes
    for round in 0..8u64 {
        let planes: Vec<QTensor> = (0..1 + rng.below(6))
            .map(|_| {
                let n = 1 + rng.below(40);
                let raw: Vec<f32> = (0..n).map(|_| rng.normal() * 4.0).collect();
                let f = [quant::FP32, quant::BF16, quant::FP8_E4M3, quant::FP4_E2M1]
                    [rng.below(4)];
                QTensor::from_slice(&[n], &raw, f)
            })
            .collect();
        let enc = encode_corrupt(&planes);
        let back = decode_corrupt(&enc).unwrap();
        assert_eq!(back.len(), planes.len(), "round {round}: plane count");
        for (i, (p, q)) in planes.iter().zip(&back).enumerate() {
            assert_eq!(p.to_bytes(), q.to_bytes(), "round {round} plane {i}");
        }
        assert!(decode_corrupt(&enc[..enc.len() - 1]).is_err(), "truncated cache");
    }

    // evaluation batches: token streams, sparse distributions, labels
    let examples: Vec<Example> = (0..5)
        .map(|_| Example {
            clean: (0..3 + rng.below(10)).map(|_| rng.below(50_000)).collect(),
            corrupt: (0..3 + rng.below(10)).map(|_| rng.below(50_000)).collect(),
            pos: rng.below(12),
            ans: (0..1 + rng.below(3)).map(|_| (rng.below(50_000), rng.f32())).collect(),
            dis: (0..rng.below(3)).map(|_| (rng.below(50_000), -rng.f32())).collect(),
            label: rng.below(50_000),
        })
        .collect();
    let enc = encode_examples(&examples);
    let back = decode_examples(&enc).unwrap();
    assert_eq!(back.len(), examples.len());
    for (i, (a, b)) in examples.iter().zip(&back).enumerate() {
        assert_eq!(a.clean, b.clean, "example {i}: clean stream");
        assert_eq!(a.corrupt, b.corrupt, "example {i}: corrupt stream");
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.label, b.label);
        for (x, y) in a.ans.iter().zip(&b.ans).chain(a.dis.iter().zip(&b.dis)) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "example {i}: sparse weight bits");
        }
    }
    assert!(decode_examples(&enc[..enc.len() - 1]).is_err(), "truncated batch");
    let mut trailing = enc.clone();
    trailing.push(0);
    assert!(decode_examples(&trailing).is_err(), "trailing garbage");
}

#[test]
fn format_bits_roundtrip_and_storage_sanity() {
    for bits in [4u32, 8, 16, 32] {
        let f = Format::by_bits(bits);
        // packed storage width round-trips the nominal bit width exactly
        assert_eq!(f.storage_bits() as u32, bits);
        if bits < 32 {
            assert!(!f.is_passthrough());
            // coarser formats have strictly larger quanta at 1.0
            let q = |f: Format| {
                let y = quant::fq(1.0 + 1e-6, f);
                (y - 1.0).abs().max(f32::EPSILON)
            };
            if bits > 4 {
                assert!(q(Format::by_bits(bits)) <= q(Format::by_bits(bits / 2)) + 1e-12);
            }
        }
    }
}
