//! Property-based tests on coordinator invariants (proptest is not
//! vendored offline; properties are driven by the in-repo xoshiro RNG
//! with fixed seeds — failures are reproducible by construction).

use pahq::gpu_sim::{CostModel, Sim, StreamId};
use pahq::metrics::{auc_pessimistic, confusion, RocPoint};
use pahq::model::{Channel, Graph};
use pahq::patching::PatchMask;
use pahq::quant::{self, Format};
use pahq::util::json::Json;
use pahq::util::rng::Rng;

fn random_graph(rng: &mut Rng) -> Graph {
    Graph {
        n_layer: 1 + rng.below(6),
        n_head: 1 + rng.below(12),
        has_mlp: rng.below(2) == 1,
    }
}

#[test]
fn graph_sources_are_causal_and_complete() {
    // For every random graph: every edge's source strictly precedes its
    // destination's compute point, sources are sorted & unique, and the
    // edge set is exactly the union over channels of their sources.
    let mut rng = Rng::new(101);
    for _ in 0..40 {
        let g = random_graph(&mut rng);
        let mut counted = 0usize;
        for ch in g.channels() {
            let srcs = g.sources(ch);
            counted += srcs.len();
            let mut sorted = srcs.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), srcs.len(), "unique");
            for &s in &srcs {
                assert!(s < g.n_nodes());
                // destination channel of layer l never reads a node of a
                // later layer
                if let Channel::Head { layer, .. } = ch {
                    match g.node_kind(s) {
                        pahq::model::graph::NodeKind::Head { layer: sl, .. } => {
                            assert!(sl < layer)
                        }
                        pahq::model::graph::NodeKind::Mlp { layer: sl } => assert!(sl < layer),
                        pahq::model::graph::NodeKind::Embed => {}
                    }
                }
            }
        }
        assert_eq!(counted, g.n_edges());
    }
}

#[test]
fn patch_mask_set_get_roundtrip() {
    let mut rng = Rng::new(202);
    for _ in 0..30 {
        let g = random_graph(&mut rng);
        let channels = g.channels();
        let mut mask = PatchMask::empty(channels.len());
        let edges = g.edges();
        // random subset in, then out
        let mut on = Vec::new();
        for e in &edges {
            if rng.below(3) == 0 {
                let ci = channels.iter().position(|c| *c == e.dst).unwrap();
                mask.set(ci, e.src, true);
                on.push((ci, e.src));
            }
        }
        assert_eq!(mask.count(), {
            let mut d = on.clone();
            d.sort_unstable();
            d.dedup();
            d.len()
        });
        for &(ci, src) in &on {
            assert!(mask.get(ci, src));
            mask.set(ci, src, false);
        }
        assert_eq!(mask.count(), 0);
    }
}

#[test]
fn fq_is_projection_and_monotone_everywhere() {
    // randomized sweep across formats and magnitudes: idempotent,
    // monotone, symmetric, bounded
    let mut rng = Rng::new(303);
    let formats = [
        quant::FP8_E4M3,
        quant::FP8_E5M2,
        quant::FP4_E2M1,
        quant::BF16,
        quant::FP16,
    ];
    for f in formats {
        let mut xs: Vec<f32> = (0..4000)
            .map(|_| {
                let e = rng.f32() * 60.0 - 30.0;
                let sign = if rng.below(2) == 0 { -1.0 } else { 1.0 };
                sign * e.exp2() * (1.0 + rng.f32())
            })
            .collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let ys: Vec<f32> = xs.iter().map(|&x| quant::fq(x, f)).collect();
        for w in ys.windows(2) {
            assert!(w[0] <= w[1], "monotone {f:?}");
        }
        for (&x, &y) in xs.iter().zip(&ys) {
            assert_eq!(quant::fq(y, f), y, "idempotent");
            assert!(y.abs() <= f.maxv, "bounded");
            assert_eq!(quant::fq(-x, f), -y, "odd symmetry");
        }
    }
}

#[test]
fn quantized_accumulation_never_beats_fp32_precision() {
    // summing n positive values: the quantized running sum is always
    // within the final clamp, and coarser formats lose at least as much
    // mass as finer ones (monotonicity of mantissa loss in mbits)
    let mut rng = Rng::new(404);
    for _ in 0..50 {
        let n = 5 + rng.below(60);
        let xs: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0).collect();
        let exact: f32 = xs.iter().sum();
        let mut err_by_fmt = Vec::new();
        for f in [quant::FP16, quant::FP8_E4M3, quant::FP4_E2M1] {
            let mut acc = vec![0.0f32];
            for &x in &xs {
                quant::accumulate_quantized(&mut acc, &[x], f);
            }
            err_by_fmt.push((acc[0] - exact).abs());
        }
        assert!(
            err_by_fmt[0] <= err_by_fmt[2] + 1e-3 * exact.abs(),
            "fp16 err {} <= fp4 err {} (exact {exact})",
            err_by_fmt[0],
            err_by_fmt[2]
        );
    }
}

#[test]
fn auc_respects_dominance_under_random_point_sets() {
    let mut rng = Rng::new(505);
    for _ in 0..50 {
        let n = 1 + rng.below(20);
        let pts: Vec<RocPoint> = (0..n)
            .map(|_| RocPoint { fpr: rng.f64(), tpr: rng.f64() })
            .collect();
        let auc = auc_pessimistic(&pts);
        assert!((0.0..=1.0).contains(&auc));
        // shifting every point up (tpr+δ clamped) never lowers AUC
        let better: Vec<RocPoint> = pts
            .iter()
            .map(|p| RocPoint { fpr: p.fpr, tpr: (p.tpr + 0.2).min(1.0) })
            .collect();
        assert!(auc_pessimistic(&better) >= auc - 1e-12);
    }
}

#[test]
fn confusion_matches_hand_counts_on_random_vectors() {
    let mut rng = Rng::new(606);
    for _ in 0..50 {
        let n = 1 + rng.below(200);
        let pred: Vec<bool> = (0..n).map(|_| rng.below(2) == 1).collect();
        let truth: Vec<bool> = (0..n).map(|_| rng.below(2) == 1).collect();
        let p = confusion(&pred, &truth);
        let tp = pred.iter().zip(&truth).filter(|(&a, &b)| a && b).count() as f64;
        let fp = pred.iter().zip(&truth).filter(|(&a, &b)| a && !b).count() as f64;
        let pos = truth.iter().filter(|&&t| t).count() as f64;
        let neg = n as f64 - pos;
        if pos > 0.0 {
            assert!((p.tpr - tp / pos).abs() < 1e-12);
        }
        if neg > 0.0 {
            assert!((p.fpr - fp / neg).abs() < 1e-12);
        }
    }
}

#[test]
fn des_makespan_bounds() {
    // makespan >= busiest stream; adding an op never decreases makespan;
    // makespan <= sum of all durations (work conservation bounds)
    let mut rng = Rng::new(707);
    for _ in 0..40 {
        let mut sim = Sim::new(3);
        let mut total = 0.0;
        let mut prev_span = 0.0;
        let mut events = Vec::new();
        for _ in 0..60 {
            let s = StreamId(rng.below(3));
            let d = rng.f64() * 20.0;
            total += d;
            let deps: Vec<_> = (0..rng.below(3).min(events.len()))
                .map(|_| events[rng.below(events.len())])
                .collect();
            let e = sim.op(s, d, &deps, "op");
            events.push(e);
            let span = sim.makespan();
            assert!(span >= prev_span, "monotone");
            prev_span = span;
        }
        let busiest = (0..3)
            .map(|s| sim.utilization(StreamId(s)) * sim.makespan())
            .fold(0.0f64, f64::max);
        assert!(sim.makespan() >= busiest - 1e-9);
        assert!(sim.makespan() <= total + 1e-9);
    }
}

#[test]
fn cost_model_monotone_in_every_argument() {
    let c = CostModel::default();
    let mut rng = Rng::new(808);
    for _ in 0..60 {
        let (m, n, k) = (1 + rng.below(4096), 1 + rng.below(4096), 1 + rng.below(4096));
        let f = quant::FP8_E4M3;
        assert!(c.gemm_us(m + 64, n, k, f) >= c.gemm_us(m, n, k, f));
        assert!(c.gemm_us(m, n + 64, k, f) >= c.gemm_us(m, n, k, f));
        let b = rng.below(1 << 24);
        assert!(c.transfer_us(b + 4096, 1) >= c.transfer_us(b, 1));
        assert!(c.transfer_us(b, 10) >= c.transfer_us(b, 1));
        assert!(c.elementwise_us(b + 4096) >= c.elementwise_us(b));
    }
}

#[test]
fn json_fuzz_roundtrip() {
    // random JSON trees survive dump -> parse exactly
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.below(1 << 20) as f64) - (1 << 19) as f64),
            3 => Json::Str(
                (0..rng.below(12))
                    .map(|_| {
                        let opts = ['a', 'Z', '"', '\\', '\n', 'ü', '7', ' '];
                        opts[rng.below(opts.len())]
                    })
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(909);
    for _ in 0..200 {
        let v = gen(&mut rng, 3);
        let back = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, back);
    }
}

#[test]
fn batched_sweep_is_bit_identical_to_serial() {
    // The sweep engine's core contract: across seeded random graphs,
    // policies (hi-override on/off), and thresholds, the batched
    // speculative sweep returns exactly the serial sweep's kept set,
    // kept count, and final metric — bit for bit.
    use pahq::acdc::sweep::{self, Candidate, FnScorer, SweepMode, SyntheticSurface};
    let mut rng = Rng::new(1010);
    for round in 0..12u64 {
        let g = random_graph(&mut rng);
        let channels = g.channels();
        // plan mirrors acdc::sweep_plan: reverse-topological channels,
        // reversed sources within each channel
        let pahq_like = round % 2 == 0;
        let mut order = channels.clone();
        order.reverse();
        let mut plan: Vec<Vec<Candidate>> = Vec::new();
        for ch in order {
            let ci = channels.iter().position(|c| *c == ch).unwrap();
            let mut srcs = g.sources(ch);
            srcs.reverse();
            plan.push(
                srcs.into_iter()
                    .map(|src| Candidate {
                        chan: ci,
                        src,
                        hi: if pahq_like { Some(src) } else { None },
                    })
                    .collect(),
            );
        }
        let surface = SyntheticSurface::new(2000 + round, 0.01);
        let tau = [0.05f32, 0.3, 0.6, 0.95][rng.below(4)];
        let score = |m: &PatchMask, c: Option<&Candidate>| surface.damage(m, c);
        let run = |mode: SweepMode, workers: usize| {
            let mut scorer = FnScorer { score, workers };
            sweep::sweep(&mut scorer, channels.len(), &plan, tau, true, mode).unwrap()
        };
        let kept = |out: &sweep::SweepOutcome| -> Vec<bool> {
            g.edges()
                .iter()
                .map(|e| {
                    let ci = channels.iter().position(|c| *c == e.dst).unwrap();
                    !out.removed.get(ci, e.src)
                })
                .collect()
        };
        let serial = run(SweepMode::Serial, 1);
        for workers in [2usize, 3, 8] {
            let batched = run(SweepMode::Batched { workers }, workers);
            assert_eq!(
                kept(&serial),
                kept(&batched),
                "kept set (round {round}, workers {workers}, tau {tau})"
            );
            assert_eq!(serial.removed_count, batched.removed_count, "kept count");
            assert_eq!(
                serial.final_metric.to_bits(),
                batched.final_metric.to_bits(),
                "final metric bits (round {round}, workers {workers})"
            );
            assert_eq!(serial.trace.len(), batched.trace.len(), "one decision per edge");
            for (a, b) in serial.trace.iter().zip(&batched.trace) {
                assert_eq!(a.removed, b.removed);
                assert_eq!(a.edges_remaining, b.edges_remaining);
                assert_eq!(a.metric.to_bits(), b.metric.to_bits());
            }
        }
    }
}

#[test]
fn format_bits_roundtrip_and_storage_sanity() {
    for bits in [4u32, 8, 16, 32] {
        let f = Format::by_bits(bits);
        assert!(f.storage_bytes() <= 4);
        if bits < 32 {
            assert!(!f.is_passthrough());
            // coarser formats have strictly larger quanta at 1.0
            let q = |f: Format| {
                let y = quant::fq(1.0 + 1e-6, f);
                (y - 1.0).abs().max(f32::EPSILON)
            };
            if bits > 4 {
                assert!(q(Format::by_bits(bits)) <= q(Format::by_bits(bits / 2)) + 1e-12);
            }
        }
    }
}
