//! Tests for the durable content-addressed artifact store: generation
//! GC semantics, concurrent-handle safety, corrupt-entry quarantine,
//! and the cold-start `--resume` contract — a fresh process against a
//! populated disk store re-runs cells without recomputing (or even
//! re-writing) any shared artifact.
//!
//! Everything here runs on the synthetic substrate (made-up model/task
//! names), so it behaves identically with or without `make artifacts`.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use pahq::api::{self, MatrixSpec, MatrixSpecBuilder, StoreSpec};
use pahq::matrix::store::{address, ArtifactStore, DiskStore};

fn tmp_root(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("pahq_storetest_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

#[test]
fn gc_collects_only_entries_beyond_the_horizon() {
    let root = tmp_root("gc");
    // gen 1: two entries; gens 2..4: one entry each; gen 5: the sweeper
    {
        let s = DiskStore::open(&root).unwrap();
        s.put("gen1/a", b"a").unwrap();
        s.put("gen1/b", b"bb").unwrap();
    }
    for g in 2..=4u64 {
        let s = DiskStore::open(&root).unwrap();
        assert_eq!(s.generation(), g, "each open bumps the generation");
        s.put(&format!("gen{g}/a"), b"xx").unwrap();
    }
    let s = DiskStore::open(&root).unwrap();
    assert_eq!(s.generation(), 5);
    let r = s.gc(2).unwrap();
    // collect iff last_used + horizon < generation: gens 1 and 2 go,
    // gens 3 and 4 stay
    assert_eq!(r.collected, 3, "both gen-1 entries plus the gen-2 one");
    assert_eq!(r.live, 2);
    assert_eq!(r.bytes_freed, 1 + 2 + 2);
    assert_eq!(r.missing, 0);
    assert!(s.get("gen1/a").unwrap().is_none());
    assert!(s.get("gen1/b").unwrap().is_none());
    assert!(s.get("gen2/a").unwrap().is_none());
    assert_eq!(s.get("gen3/a").unwrap().unwrap(), b"xx");
    assert_eq!(s.get("gen4/a").unwrap().unwrap(), b"xx");
    // those reads stamped the survivors at gen 5: even the tightest
    // horizon keeps an entry touched within it
    let r = s.gc(1).unwrap();
    assert_eq!((r.collected, r.live), (0, 2), "touched entries never collect");
    // a vanished file is a dropped manifest row, not a collection
    let addr = address("gen3/a");
    std::fs::remove_file(root.join(&addr[..2]).join(&addr[2..])).unwrap();
    let r = s.gc(1).unwrap();
    assert_eq!((r.missing, r.live), (1, 1));
    assert!(s.get("gen3/a").unwrap().is_none());
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn concurrent_handles_never_collect_each_others_live_artifacts() {
    // Two processes sharing one store root: each opens its own handle
    // (adjacent generations), touches its own artifacts, and sweeps —
    // with any horizon >= 1 neither sweep collects the other's live
    // entries; only the genuinely stale one goes.
    let root = tmp_root("concurrent");
    {
        let s = DiskStore::open(&root).unwrap();
        s.put("live/a", b"aa").unwrap();
        s.put("live/b", b"bb").unwrap();
        s.put("stale/z", b"zz").unwrap();
    }
    for _ in 0..3 {
        DiskStore::open(&root).unwrap();
    }
    let a = DiskStore::open(&root).unwrap();
    let b = DiskStore::open(&root).unwrap();
    assert_eq!(a.generation() + 1, b.generation(), "adjacent generations");
    assert!(a.get("live/a").unwrap().is_some(), "handle A touches its artifact");
    assert!(b.get("live/b").unwrap().is_some(), "handle B touches its artifact");
    let ra = a.gc(1).unwrap();
    let rb = b.gc(1).unwrap();
    assert_eq!(ra.collected, 1, "A's sweep takes only the stale entry");
    assert_eq!(rb.collected, 0, "B's sweep finds nothing left to take");
    // both live artifacts survive both sweeps, visible through either
    // handle (merge-on-write keeps the freshest stamp on disk)
    for handle in [&a, &b] {
        assert_eq!(handle.get("live/a").unwrap().unwrap(), b"aa");
        assert_eq!(handle.get("live/b").unwrap().unwrap(), b"bb");
        assert!(handle.get("stale/z").unwrap().is_none());
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn corrupt_entries_quarantine_instead_of_failing() {
    let root = tmp_root("quarantine");
    let s = DiskStore::open(&root).unwrap();
    let key = "scores/eap/synthetic-m/alpha/0/synthetic";
    s.put(key, b"payload-bytes").unwrap();
    let addr = address(key);
    let shard = root.join(&addr[..2]).join(&addr[2..]);
    assert!(shard.exists());
    // flip the file to garbage under the store's feet (torn write,
    // disk fault, hostile edit — all the same to the checksum)
    std::fs::write(&shard, b"not an artifact").unwrap();
    assert!(s.get(key).unwrap().is_none(), "corrupt entry reads as a miss, not a panic");
    assert!(!shard.exists(), "the bad file left the shard tree");
    assert!(root.join("quarantine").join(&addr).exists(), "evidence kept aside");
    assert!(!s.entries().contains_key(&addr), "manifest row dropped");
    assert!(!s.contains(key).unwrap());
    // the address is reusable: a fresh put repopulates and verifies
    s.put(key, b"payload-bytes").unwrap();
    assert_eq!(s.get(key).unwrap().unwrap(), b"payload-bytes");
    // GC walks the shard tree only — quarantined files are never touched
    s.gc(1).unwrap();
    assert!(root.join("quarantine").join(&addr).exists());
    std::fs::remove_dir_all(&root).ok();
}

/// Every artifact file currently in the shard tree, as `ab/cdef…`
/// relative names (manifest, tmp/, and quarantine/ excluded).
fn shard_files(root: &Path) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    for dir in std::fs::read_dir(root).unwrap() {
        let dir = dir.unwrap();
        let name = dir.file_name().to_string_lossy().to_string();
        if !dir.path().is_dir() || name.len() != 2 {
            continue;
        }
        for f in std::fs::read_dir(dir.path()).unwrap() {
            out.insert(format!("{name}/{}", f.unwrap().file_name().to_string_lossy()));
        }
    }
    out
}

fn disk_builder(base: &Path, store_root: &Path) -> MatrixSpecBuilder {
    MatrixSpec::builder()
        .models(&["synthetic-m".to_string()])
        .tasks(&["alpha".to_string(), "beta".to_string()])
        .workers(2)
        .faithfulness(false)
        .store(StoreSpec::Disk { root: store_root.to_path_buf(), gc_horizon: None })
        .json_path(base.join("matrix.json"))
        .out_dir(base.to_path_buf())
}

#[test]
fn cold_start_resume_recomputes_no_artifacts() {
    // The acceptance contract: populate a disk store with one grid run,
    // then resume from a fresh process state. With records intact the
    // resume is a no-op (byte-identical records); with records deleted
    // every cell re-runs all-hit against the store — same kept sets,
    // and not a single new artifact file written.
    let base = tmp_root("resume");
    let store_root = base.join("store");
    let spec = disk_builder(&base, &store_root).build().unwrap();
    let first = api::matrix(&spec).unwrap();
    assert_eq!(first.manifest.aggregate.n_error, 0);
    let n_cells = first.manifest.cells.len();
    let hashes: Vec<Option<String>> =
        first.manifest.cells.iter().map(|c| c.kept_hash.clone()).collect();
    let artifacts = shard_files(&store_root);
    assert!(!artifacts.is_empty(), "the grid published artifacts durably");

    let record_paths: Vec<PathBuf> =
        spec.cells().iter().map(|c| base.join(c.record_name())).collect();
    let before: Vec<Vec<u8>> =
        record_paths.iter().map(|p| std::fs::read(p).unwrap()).collect();

    // resume with everything intact: pure cache, records byte-identical
    let second = api::matrix(&disk_builder(&base, &store_root).resume(true).build().unwrap())
        .unwrap();
    assert_eq!(second.manifest.aggregate.n_cached, n_cells, "nothing re-ran");
    for (path, bytes) in record_paths.iter().zip(&before) {
        assert_eq!(&std::fs::read(path).unwrap(), bytes, "cached record untouched");
    }

    // cold start: records gone, store intact — cells re-run all-hit
    for p in &record_paths {
        std::fs::remove_file(p).unwrap();
    }
    let third = api::matrix(&disk_builder(&base, &store_root).resume(true).build().unwrap())
        .unwrap();
    assert_eq!(third.manifest.aggregate.n_error, 0);
    assert_eq!(third.manifest.aggregate.n_ok, n_cells, "every cell re-ran");
    for (i, cell) in third.manifest.cells.iter().enumerate() {
        assert_eq!(cell.status.as_str(), "ok");
        assert_eq!(cell.kept_hash, hashes[i], "re-run rediscovers the same circuit");
        let stats = cell.cache.as_ref().expect("every re-run cell pulled from the store");
        assert!(stats.corrupt_hit, "{}: corrupt-analog served from disk", cell.method);
        assert_eq!(
            stats.scores_hit,
            cell.method != "acdc",
            "{}: scores served from disk",
            cell.method
        );
    }
    assert_eq!(
        shard_files(&store_root),
        artifacts,
        "zero artifacts recomputed or re-written on the cold resume"
    );
    std::fs::remove_dir_all(&base).ok();
}
