//! Load-harness contracts: histogram quantiles against a sorted-vector
//! oracle, scenario `FromStr`/`Display` round-trips with field-named
//! validation errors, deterministic open-loop schedules, and small
//! end-to-end runs in both direct and wire mode.

use std::time::Duration;

use pahq::load::{self, Histogram, LoadConfig, LoadMode, ReqKind, Scenario};
use pahq::serve::{ServeConfig, Server};
use pahq::util::rng::Rng;

// ---------------------------------------------------------------------------
// Histogram vs sorted-vector oracle

/// Nearest-rank quantile over the raw samples — the ground truth the
/// log2 histogram's bounds must bracket.
fn oracle(samples: &mut [u64], q: f64) -> u64 {
    samples.sort_unstable();
    let n = samples.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    samples[rank - 1]
}

fn check_bounds(samples: &[u64], q: f64) {
    let mut h = Histogram::new();
    for &v in samples {
        h.record_us(v);
    }
    let mut sorted = samples.to_vec();
    let truth = oracle(&mut sorted, q);
    let (lo, hi) = h.quantile_bounds(q).expect("non-empty histogram");
    assert!(
        lo <= truth && truth <= hi,
        "q={q}: oracle {truth} outside bracket [{lo}, {hi}] for {} samples",
        samples.len()
    );
    // the reported value is the bracket's upper bound
    assert_eq!(h.quantile_us(q), hi);
}

#[test]
fn quantile_bounds_bracket_the_oracle_on_random_samples() {
    let mut rng = Rng::new(0x10ad);
    for _trial in 0..50 {
        let n = 1 + rng.below(400);
        // mix scales: sub-microsecond ties, mid-range, and huge tails
        let samples: Vec<u64> = (0..n)
            .map(|_| match rng.below(3) {
                0 => rng.below(16) as u64,
                1 => rng.below(100_000) as u64,
                _ => (rng.below(1_000_000) as u64) * 4096,
            })
            .collect();
        for q in [0.01, 0.5, 0.9, 0.99, 1.0] {
            check_bounds(&samples, q);
        }
    }
}

#[test]
fn single_sample_and_all_equal_quantiles_are_exact() {
    for v in [0u64, 1, 7, 1023, 1024, u64::from(u32::MAX)] {
        let mut h = Histogram::new();
        h.record_us(v);
        for q in [0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_bounds(q), Some((v, v)), "single sample {v}");
            assert_eq!(h.quantile_us(q), v);
        }
    }
    let mut h = Histogram::new();
    for _ in 0..57 {
        h.record_us(12_345);
    }
    assert_eq!(h.quantile_bounds(0.5), Some((12_345, 12_345)));
    assert_eq!(h.quantile_us(0.99), 12_345);
    assert_eq!(h.max_us(), 12_345);
    assert_eq!(h.min_us(), 12_345);
}

#[test]
fn merge_is_associative_and_matches_whole() {
    let mut rng = Rng::new(99);
    let parts: Vec<Vec<u64>> = (0..3)
        .map(|_| (0..rng.below(200)).map(|_| rng.below(1 << 20) as u64).collect())
        .collect();

    let hist = |vals: &[u64]| {
        let mut h = Histogram::new();
        for &v in vals {
            h.record_us(v);
        }
        h
    };
    let (a, b, c) = (hist(&parts[0]), hist(&parts[1]), hist(&parts[2]));

    // (a ⊕ b) ⊕ c
    let mut left = a.clone();
    left.merge(&b);
    left.merge(&c);
    // a ⊕ (b ⊕ c)
    let mut bc = b.clone();
    bc.merge(&c);
    let mut right = a.clone();
    right.merge(&bc);
    assert_eq!(left, right, "merge must be associative");

    // merging per-thread parts equals recording everything in one
    let all: Vec<u64> = parts.iter().flatten().copied().collect();
    assert_eq!(left, hist(&all), "merged parts must equal the whole");
    if !all.is_empty() {
        check_bounds(&all, 0.99);
    }
}

// ---------------------------------------------------------------------------
// Scenario parsing

#[test]
fn preset_display_round_trips_bare() {
    for name in load::PRESETS {
        let sc: Scenario = name.parse().unwrap();
        assert_eq!(sc.to_string(), name, "bare preset must display as its name");
        let again: Scenario = sc.to_string().parse().unwrap();
        assert_eq!(again, sc);
    }
}

#[test]
fn overrides_round_trip_through_display() {
    for spec in [
        "smoke:clients=4",
        "smoke:rate=12.5,duration=2.5",
        "steady:clients=8,seed=7",
        "burst:burst=16,mix=1/0/0",
        "saturate:stages=2,rate_step=1.5",
        "smoke:mix=0.5/0.25/0.25",
    ] {
        let sc: Scenario = spec.parse().unwrap();
        let shown = sc.to_string();
        let again: Scenario = shown.parse().unwrap();
        assert_eq!(again, sc, "{spec} -> {shown} must round-trip");
    }
    // display emits only the overridden keys
    let sc: Scenario = "smoke:clients=4".parse().unwrap();
    assert_eq!(sc.to_string(), "smoke:clients=4");
}

#[test]
fn validation_errors_are_field_named() {
    for (spec, field) in [
        ("smoke:clients=0", "clients:"),
        ("smoke:clients=banana", "clients:"),
        ("smoke:rate=-1", "rate:"),
        ("smoke:duration=0", "duration:"),
        ("smoke:stages=0", "stages:"),
        ("smoke:rate_step=0", "rate_step:"),
        ("smoke:burst=0", "burst:"),
        ("smoke:mix=1/0", "mix:"),
        ("smoke:mix=0/0/0", "mix:"),
        ("smoke:mix=a/b/c", "mix:"),
        ("warp", "scenario:"),
        ("smoke:warp=1", "scenario:"),
        ("smoke:", "scenario:"),
        ("smoke:clients", "scenario:"),
    ] {
        let err = spec.parse::<Scenario>().expect_err(spec).to_string();
        assert!(
            err.starts_with(field),
            "'{spec}' must fail with a '{field}'-prefixed error, got: {err}"
        );
    }
}

// ---------------------------------------------------------------------------
// Deterministic schedules

#[test]
fn identical_seed_scenario_and_workers_give_identical_schedules() {
    let sc: Scenario = "saturate:duration=2".parse().unwrap();
    assert_eq!(sc.schedule(), sc.schedule(), "schedule must be a pure function");

    let again: Scenario = "saturate:duration=2".parse().unwrap();
    assert_eq!(sc.schedule(), again.schedule());

    // a different seed must actually change the plan
    let reseeded: Scenario = "saturate:duration=2,seed=1".parse().unwrap();
    assert_ne!(sc.schedule(), reseeded.schedule());
}

#[test]
fn workers_override_changes_only_client_assignment() {
    let base: Scenario = "smoke:duration=8,rate=12".parse().unwrap();
    let wide = base.clone().with_clients(7).unwrap();
    let (a, b) = (base.schedule(), wide.schedule());
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!((x.at, x.stage, x.kind, x.task_idx), (y.at, y.stage, y.kind, y.task_idx));
        assert!(y.client < 7);
    }
}

#[test]
fn schedule_covers_stages_and_respects_the_mix() {
    let sc: Scenario = "saturate:duration=2,stages=3".parse().unwrap();
    let plan = sc.schedule();
    assert!(!plan.is_empty());
    for stage in 0..3 {
        assert!(plan.iter().any(|r| r.stage == stage), "stage {stage} must schedule work");
    }
    // saturate's mix is run-only
    assert!(plan.iter().all(|r| r.kind == ReqKind::Run));
    // arrivals are time-ordered within a stage
    for w in plan.windows(2) {
        if w[0].stage == w[1].stage {
            assert!(w[0].at <= w[1].at);
        }
    }
    // offered rate doubles per stage
    assert_eq!(sc.stage_rate(0), 8.0);
    assert_eq!(sc.stage_rate(2), 32.0);
}

// ---------------------------------------------------------------------------
// End-to-end (small): direct mode and wire mode against a live server

fn snapshot_invariants(doc: &pahq::util::json::Json) {
    let get = |path: &[&str]| {
        let mut cur = doc;
        for k in path {
            cur = cur.get(k).unwrap();
        }
        cur.as_f64().unwrap()
    };
    assert_eq!(doc.get("kind").unwrap().as_str().unwrap(), "load_snapshot");
    let submitted = get(&["requests", "submitted"]);
    assert!(submitted > 0.0);
    assert_eq!(
        submitted,
        get(&["requests", "ok"]) + get(&["requests", "failed"]) + get(&["requests", "cancelled"]),
        "every submitted request must be accounted for"
    );
    assert_eq!(get(&["requests", "failed"]), 0.0, "no request may fail");
    assert_eq!(get(&["frames", "errors"]), 0.0);
    let p99 = get(&["latency_us", "p99"]);
    assert!(get(&["latency_us", "p50"]) <= p99 && p99 <= get(&["latency_us", "max"]));
}

#[test]
fn direct_mode_runs_a_tiny_scenario_end_to_end() {
    let dir = std::env::temp_dir().join(format!("pahq_load_direct_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = LoadConfig {
        scenario: "smoke:clients=2,rate=10,duration=1,mix=1/0/0".parse().unwrap(),
        mode: LoadMode::Direct,
        json: Some(dir.join("load_snapshot.json")),
    };
    let doc = load::run(&cfg).unwrap();
    snapshot_invariants(&doc);
    assert_eq!(doc.get("mode").unwrap().as_str().unwrap(), "direct");
    // the snapshot on disk is byte-identical to the returned document
    let disk =
        pahq::util::json::Json::parse_file(&dir.join("load_snapshot.json")).unwrap();
    assert_eq!(disk, doc);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wire_mode_drives_a_live_daemon_and_drains_it() {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let cfg = LoadConfig {
        scenario: "smoke:clients=2,rate=10,duration=1".parse().unwrap(),
        mode: LoadMode::Wire { addr: addr.to_string(), shutdown: true },
        json: None,
    };
    let doc = load::run(&cfg).unwrap();
    snapshot_invariants(&doc);
    assert_eq!(doc.get("mode").unwrap().as_str().unwrap(), "wire");
    assert!(doc.get("frames").unwrap().get("received").unwrap().as_f64().unwrap() > 0.0);

    // --shutdown asked the daemon to drain; its run() must return a
    // report that accounts for the jobs the load run submitted
    let report = handle.join().unwrap();
    assert!(report.jobs > 0);
    assert_eq!(report.cells_failed, 0);
    assert!(report.connections >= 2, "one connection per load client plus the shutdown one");
    std::thread::sleep(Duration::from_millis(10));
}
