//! Cross-module integration tests over the public API: full
//! artifact-chain scenarios a downstream user would actually run.
//! Every test skips gracefully when `make artifacts` hasn't been run.

use pahq::acdc::{self, AcdcConfig};
use pahq::baselines::{eap, hisp};
use pahq::eval;
use pahq::experiments::complement_mask;
use pahq::metrics::{
    answer_accuracy, confusion, faithfulness, logit_diff, Objective,
};
use pahq::patching::{PatchedForward, Policy};
use pahq::quant::{FP4_E2M1, FP8_E4M3};

fn engine(model: &str, task: &str) -> Option<PatchedForward> {
    std::env::set_var("PAHQ_ATTN", "ref");
    match PatchedForward::new(model, task) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping (artifacts not built?): {e}");
            None
        }
    }
}

/// The headline end-to-end scenario: PAHQ discovers (nearly) the same
/// circuit as FP32 ACDC at a fixed threshold, on every task.
#[test]
fn pahq_recovers_acdc_circuit_across_tasks() {
    for task in ["ioi", "greater_than", "docstring"] {
        let Some(mut e) = engine("redwood2l-sim", task) else { return };
        let cfg = AcdcConfig::new(0.01, Objective::Kl);
        let fp32 = acdc::run(&mut e, &cfg).unwrap();
        e.set_session(Policy::pahq(FP8_E4M3)).unwrap();
        let pahq = acdc::run(&mut e, &cfg).unwrap();
        let agree = fp32
            .kept
            .iter()
            .zip(&pahq.kept)
            .filter(|(a, b)| a == b)
            .count();
        let frac = agree as f64 / fp32.kept.len() as f64;
        assert!(frac > 0.9, "{task}: PAHQ/ACDC circuit agreement {frac:.3}");
    }
}

/// Discovered circuits are *faithful*: running the model with only the
/// circuit's edges (everything else corrupted) preserves the behaviour.
#[test]
fn discovered_circuit_is_faithful_and_minimal() {
    let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
    let res = acdc::run(&mut e, &AcdcConfig::new(0.01, Objective::Kl)).unwrap();
    assert!(res.n_kept < e.graph.n_edges() / 4, "sparse: {}", res.n_kept);

    let m_clean = logit_diff(&e.clean_logits, &e.examples);
    let nothing = complement_mask(&e, &vec![false; e.graph.n_edges()]);
    let m_corrupt = logit_diff(&e.forward(&nothing, None).unwrap(), &e.examples);
    let circuit_logits = e.forward(&res.removed, None).unwrap();
    let m_circ = logit_diff(&circuit_logits, &e.examples);
    let f = faithfulness(m_circ, m_clean, m_corrupt);
    assert!(f > 0.6, "circuit faithfulness {f:.3}");
    // and the circuit still answers correctly
    let acc = answer_accuracy(&circuit_logits, &e.examples);
    assert!(acc > 0.8, "circuit answer accuracy {acc}");
    // the complement (corrupting the circuit, keeping the rest) destroys it
    let inverse: Vec<bool> = res.kept.iter().map(|k| !k).collect();
    let m_inv = logit_diff(&e.forward(&complement_mask(&e, &inverse), None).unwrap(), &e.examples);
    assert!(
        faithfulness(m_inv, m_clean, m_corrupt) < 0.5,
        "anti-circuit keeps the behaviour?"
    );
}

/// Gradient baselines rank the true circuit highly on a model where
/// exhaustive ground truth is cheap.
#[test]
fn gradient_baselines_rank_circuit_edges() {
    let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
    let gt = eval::ground_truth(&mut e, "redwood2l-sim", "ioi", Objective::Kl).unwrap();
    for (name, scores) in [
        ("eap", eap::scores(&mut e, Objective::LogitDiff).unwrap()),
        ("hisp", hisp::scores(&mut e, Objective::LogitDiff).unwrap()),
    ] {
        let sweep = eval::sweep_scores(&scores, &gt);
        assert!(sweep.auc > 0.5, "{name}: AUC {:.3} beats chance", sweep.auc);
    }
}

/// Tab. 5's knee as an invariant: 8-bit PAHQ tracks FP32; 4-bit RTN
/// collapses (the paper's section-2 underflow at full strength).
#[test]
fn four_bit_collapse_eight_bit_survives() {
    let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
    let gt = eval::ground_truth(&mut e, "redwood2l-sim", "ioi", Objective::Kl).unwrap();
    let cfg = AcdcConfig::new(0.002, Objective::Kl);

    e.set_session(Policy::pahq(FP8_E4M3)).unwrap();
    let r8 = acdc::run(&mut e, &cfg).unwrap();
    let p8 = confusion(&r8.kept, &gt.member);
    assert!(p8.tpr >= 0.8, "8-bit PAHQ TPR {:.2}", p8.tpr);

    e.set_session(Policy::rtn(FP4_E2M1)).unwrap();
    let r4 = acdc::run(&mut e, &cfg).unwrap();
    let p4 = confusion(&r4.kept, &gt.member);
    assert!(
        p4.tpr <= 0.4,
        "4-bit RTN should lose most of the circuit (TPR {:.2})",
        p4.tpr
    );
}

/// Objective consistency: the KL and task-metric sweeps find heavily
/// overlapping circuits (paper Tab. 1 reports both).
#[test]
fn kl_and_task_objectives_agree_on_strong_edges() {
    let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
    let kl = acdc::run(&mut e, &AcdcConfig::new(0.01, Objective::Kl)).unwrap();
    let ld = acdc::run(&mut e, &AcdcConfig::new(0.05, Objective::LogitDiff)).unwrap();
    // every strong edge the KL run keeps with big margin shows up in task
    let both = kl
        .kept
        .iter()
        .zip(&ld.kept)
        .filter(|(a, b)| **a && **b)
        .count();
    assert!(both >= 1, "objectives share circuit edges (kl {} / ld {})", kl.n_kept, ld.n_kept);
}

/// Engine robustness: switching sessions back and forth leaves results
/// bit-identical (no state leaks between policies).
#[test]
fn session_switching_is_hermetic() {
    let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
    let patches = e.empty_patches();
    let a1 = e.forward(&patches, None).unwrap();
    e.set_session(Policy::rtn(FP8_E4M3)).unwrap();
    let _ = e.forward(&patches, None).unwrap();
    e.set_session(Policy::fp32()).unwrap();
    let a2 = e.forward(&patches, None).unwrap();
    assert_eq!(a1.data, a2.data, "fp32 results identical after RTN detour");
}

/// Dataset rotation (Edge Pruning's workload path) keeps the engine
/// consistent: references refresh, shapes stay fixed.
#[test]
fn set_examples_refreshes_references() {
    let Some(mut e) = engine("redwood2l-sim", "ioi") else { return };
    let v = pahq::tasks::Vocab::load().unwrap();
    let before = e.ref_probs.clone();
    let fresh = v.make_dataset("ioi", e.manifest.batch, 4242).unwrap();
    e.set_examples(fresh).unwrap();
    assert_eq!(e.ref_probs.len(), before.len());
    assert!(e.ref_probs.iter().zip(&before).any(|(a, b)| a != b));
    // still a working engine
    let patches = e.empty_patches();
    let d = e.damage(&patches, None, Objective::Kl).unwrap();
    assert!(d.abs() < 1e-5);
}

/// The whole scale series loads and answers (appendix C path).
#[test]
fn scale_models_load_and_run() {
    for model in ["gpt2m-sim"] {
        let Some(mut e) = engine(model, "ioi") else { return };
        let acc = answer_accuracy(&e.clean_logits, &e.examples);
        assert!(acc > 0.9, "{model} clean accuracy {acc}");
        let patches = e.empty_patches();
        let logits = e.forward(&patches, None).unwrap();
        assert_eq!(
            logits.shape,
            vec![e.manifest.batch, e.manifest.seq_len, e.manifest.vocab]
        );
    }
}
