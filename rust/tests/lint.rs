//! Integration tests for the `pahq lint` subsystem: every rule family
//! against its fixture pair under rust/src/lint/fixtures/, the pragma
//! grammar, the ratchet baseline round trip, and — the acceptance pin
//! — the repo itself linting clean at HEAD against the committed
//! `LINT_baseline.json`.

use std::path::{Path, PathBuf};

use pahq::lint::lexer;
use pahq::lint::rules::concurrency::{check_lock_order, LockDecl};
use pahq::lint::rules::{self, lint_source};
use pahq::lint::{
    gate, lint_paths, lint_repo, repo_root_from, Baseline, Finding, Severity, BASELINE_NAME,
};

/// Checkout root, reached by ascending from the crate directory.
fn root() -> PathBuf {
    repo_root_from(Path::new(env!("CARGO_MANIFEST_DIR"))).unwrap()
}

const FIXDIR: &str = "rust/src/lint/fixtures";

fn fixture_src(name: &str) -> (String, String) {
    let rel = format!("{FIXDIR}/{name}");
    let src = std::fs::read_to_string(root().join(&rel)).unwrap();
    (rel, src)
}

fn lint_fixture(name: &str) -> Vec<Finding> {
    let (rel, src) = fixture_src(name);
    lint_source(&rel, &src)
}

#[test]
fn bad_panic_fixture_fires_every_panic_surface_rule() {
    let fs = lint_fixture("bad_panic.rs");
    for rule in ["panic-unwrap", "panic-expect", "panic-macro", "slice-index"] {
        assert!(fs.iter().any(|f| f.rule == rule && !f.suppressed), "missing {rule}");
    }
    assert!(fs.iter().all(|f| f.severity == Severity::Ratchet), "panic rules are ratcheted");
}

#[test]
fn clean_panic_fixture_is_silent() {
    assert!(lint_fixture("clean_panic.rs").is_empty());
}

#[test]
fn bad_lock_fixture_fires_lock_unwrap_as_an_error() {
    let fs = lint_fixture("bad_lock.rs");
    let hit = fs.iter().find(|f| f.rule == "lock-unwrap").expect("lock-unwrap fires");
    assert_eq!(hit.severity, Severity::Error);
    assert!(!hit.suppressed);
}

#[test]
fn clean_lock_fixture_is_silent() {
    assert!(lint_fixture("clean_lock.rs").is_empty());
}

#[test]
fn bad_spawn_fixture_fires_bare_spawn_outside_allowed_dirs() {
    let fs = lint_fixture("bad_spawn.rs");
    let hit = fs.iter().find(|f| f.rule == "bare-spawn").expect("bare-spawn fires");
    assert_eq!(hit.severity, Severity::Error);
    // the same source under serve/ is allowed
    let (_, src) = fixture_src("bad_spawn.rs");
    assert!(lint_source("rust/src/serve/writer.rs", &src).is_empty());
}

#[test]
fn clean_spawn_fixture_is_silent() {
    assert!(lint_fixture("clean_spawn.rs").is_empty());
}

#[test]
fn justified_pragma_suppresses_and_records_its_justification() {
    let fs = lint_fixture("pragma_ok.rs");
    assert!(!fs.iter().any(|f| f.rule == "bad-pragma"));
    let u = fs.iter().find(|f| f.rule == "panic-unwrap").expect("finding still reported");
    assert!(u.suppressed, "justified pragma suppresses");
    assert!(u.justification.as_deref().unwrap_or("").contains("fixture"));
    assert!(fs.iter().all(|f| f.suppressed), "nothing unsuppressed in pragma_ok.rs");
}

#[test]
fn unjustified_or_unknown_pragmas_are_rejected_and_do_not_suppress() {
    let fs = lint_fixture("pragma_bad.rs");
    let bad: Vec<_> = fs.iter().filter(|f| f.rule == "bad-pragma").collect();
    assert_eq!(bad.len(), 2, "missing justification + unknown rule");
    assert!(bad.iter().all(|f| f.severity == Severity::Error));
    let unwraps: Vec<_> = fs.iter().filter(|f| f.rule == "panic-unwrap").collect();
    assert_eq!(unwraps.len(), 2);
    assert!(unwraps.iter().all(|f| !f.suppressed), "malformed pragmas never suppress");
}

fn fixture_table(file: &'static str) -> Vec<LockDecl> {
    vec![
        LockDecl { file, field: "outer", rank: 1, holder: "Pair" },
        LockDecl { file, field: "inner", rank: 2, holder: "Pair" },
    ]
}

#[test]
fn lock_order_fixture_pair_separates_good_from_bad_nesting() {
    let (_, src) = fixture_src("bad_order.rs");
    let rel: &'static str = "rust/src/lint/fixtures/bad_order.rs";
    let lx = lexer::analyze(&src);
    let hits = check_lock_order(&fixture_table(rel), rel, &lx.masked);
    assert!(
        hits.iter().any(|h| h.2.contains("violates the declared lock order")),
        "reversed nesting must be flagged: {hits:?}"
    );

    let (_, src) = fixture_src("clean_order.rs");
    let rel: &'static str = "rust/src/lint/fixtures/clean_order.rs";
    let lx = lexer::analyze(&src);
    assert!(check_lock_order(&fixture_table(rel), rel, &lx.masked).is_empty());
}

#[test]
fn ratchet_regresses_against_empty_baseline_and_passes_against_its_own() {
    let rel = format!("{FIXDIR}/bad_panic.rs");
    let report = lint_paths(&root(), &[rel]).unwrap();
    let s = gate(&report, &Baseline::default());
    assert!(!s.passed(), "fixture findings regress an empty baseline");
    assert!(s.regressions > 0);
    assert_eq!(s.errors, 0, "bad_panic.rs carries only ratcheted findings");

    let own = Baseline::from_report(&report);
    assert!(gate(&report, &own).passed(), "a report passes its own snapshot");
}

#[test]
fn baseline_round_trips_through_disk() {
    let report = lint_paths(&root(), &[format!("{FIXDIR}/bad_panic.rs")]).unwrap();
    let dir = std::env::temp_dir().join("pahq_lint_integration_baseline");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(BASELINE_NAME);
    Baseline::from_report(&report).save(&path).unwrap();
    let loaded = Baseline::load(&path).unwrap();
    assert!(gate(&report, &loaded).passed(), "saved counts reload exactly");

    // the same report against a clean file's (empty) snapshot regresses
    let clean = lint_paths(&root(), &[format!("{FIXDIR}/clean_panic.rs")]).unwrap();
    assert!(!gate(&report, &Baseline::from_report(&clean)).passed());
    std::fs::remove_file(&path).ok();
}

#[test]
fn repo_is_lint_clean_at_head() {
    let root = root();
    let report = lint_repo(&root).unwrap();
    let baseline = Baseline::load(&root.join(BASELINE_NAME)).unwrap();
    let s = gate(&report, &baseline);
    for f in report.findings.iter().filter(|f| f.severity == Severity::Error && !f.suppressed) {
        eprintln!("error[{}] {}:{}: {}", f.rule, f.file, f.line, f.message);
    }
    for r in s.rows.iter().filter(|r| r.count > r.baseline) {
        eprintln!("regression[{}] {}: {} > baseline {}", r.rule, r.file, r.count, r.baseline);
    }
    assert!(s.passed(), "{} errors, {} ratchet regressions at HEAD", s.errors, s.regressions);
}

#[test]
fn hot_paths_carry_no_unsuppressed_panic_surface_beyond_slice_index() {
    let report = lint_repo(&root()).unwrap();
    for ((rule, file), n) in report.ratchet_counts() {
        if rule == "slice-index" {
            continue;
        }
        for dir in ["rust/src/serve/", "rust/src/load/", "rust/src/matrix/"] {
            assert!(!file.starts_with(dir), "{n} unsuppressed {rule} in hot path {file}");
        }
    }
}

#[test]
fn committed_baseline_lists_only_ratcheted_rules() {
    let baseline = Baseline::load(&root().join(BASELINE_NAME)).unwrap();
    assert!(!baseline.rules.is_empty(), "LINT_baseline.json missing or empty");
    for rule_id in baseline.rules.keys() {
        let info = rules::rule(rule_id).expect("baseline rule is registered");
        assert_eq!(info.severity, Severity::Ratchet, "{rule_id} is not ratcheted");
    }
}

#[test]
fn lint_rules_doc_has_a_section_per_registered_rule() {
    let doc = std::fs::read_to_string(root().join("docs/lint_rules.md")).unwrap();
    for r in rules::RULES {
        let header = format!("## `{}`", r.id);
        assert!(doc.contains(&header), "docs/lint_rules.md missing section {header}");
    }
}
