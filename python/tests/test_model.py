"""L2 model invariants: the decomposed forward must equal a manually
chained per-layer evaluation (the exact contract the Rust engine relies
on), gradients must match finite differences, and the gate/edge-mask
forwards must degenerate correctly."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import tasks
from compile.model import (
    attn_layer,
    combined_metric,
    embed,
    forward_edge_masked,
    forward_full,
    forward_with_eps,
    forward_with_gates,
    fp32_qp,
    get_config,
    init_params,
    mlp_layer,
    param_spec,
    flatten_params,
    unflatten_params,
    unembed,
    zero_eps,
    ATTN_PARAMS,
    MLP_PARAMS,
)

CFG = dataclasses.replace(get_config("gpt2s-sim", tasks.VOCAB_SIZE), batch=2)
CFG_AO = dataclasses.replace(get_config("redwood2l-sim", tasks.VOCAB_SIZE), batch=2)


def setup(cfg, seed=0):
    params = init_params(cfg, seed)
    exs = tasks.make_dataset("ioi", cfg.batch, seed)
    clean, corrupt, pos, ans, dis, _ = tasks.batch_arrays(exs)
    return params, map(jnp.asarray, (clean, corrupt, pos, ans, dis))


def chained_forward(cfg, params, onehot):
    """Reference re-implementation of the Rust engine's chaining: assemble
    per-channel inputs as the sum of upstream node outputs and call the
    per-layer entry points."""
    nodes = [embed(onehot, params["wte"], params["wpe"])]
    qp = fp32_qp(cfg)
    for l in range(cfg.n_layer):
        resid = sum(nodes)
        x = jnp.broadcast_to(resid[:, None], (cfg.batch, cfg.n_head) + resid.shape[1:])
        w = [params[f"l{l}.{n}"] for n in ATTN_PARAMS]
        houts = attn_layer(x, x, x, *w, qp, use_pallas=True)
        for h in range(cfg.n_head):
            nodes.append(houts[:, h])
        if cfg.has_mlp:
            wm = [params[f"l{l}.{n}"] for n in MLP_PARAMS]
            nodes.append(mlp_layer(sum(nodes), *wm, jnp.asarray([99.0, -126.0, 3.4e38])))
    return unembed(sum(nodes), params["lnf_g"], params["wu"])


@pytest.mark.parametrize("cfg", [CFG, CFG_AO], ids=["mlp", "attn-only"])
def test_chained_equals_monolithic(cfg):
    params, (clean, *_rest) = setup(cfg)
    mono = forward_full(cfg, params, clean)
    chain = chained_forward(cfg, params, clean)
    np.testing.assert_allclose(np.asarray(chain), np.asarray(mono),
                               rtol=2e-4, atol=2e-4)


def test_param_roundtrip():
    params = init_params(CFG, 3)
    flat = flatten_params(CFG, params)
    back = unflatten_params(CFG, flat)
    for name, _ in param_spec(CFG):
        np.testing.assert_array_equal(np.asarray(params[name]),
                                      np.asarray(back[name]))


def test_eps_grads_match_finite_difference():
    """dmetric/d(eps_q) from the grads artifact path equals central
    finite differences on a few random coordinates."""
    cfg = dataclasses.replace(CFG_AO, batch=1)
    params = init_params(cfg, 1)
    exs = tasks.make_dataset("ioi", 1, 5)
    clean, _, pos, ans, dis, _ = (jnp.asarray(a) for a in tasks.batch_arrays(exs))
    ref_probs = jnp.full((1, cfg.vocab), 1.0 / cfg.vocab)

    def f(eps):
        m, _ = forward_with_eps(cfg, params, clean, pos, ans, dis, ref_probs,
                                jnp.float32(1.0), eps)
        return m

    eps0 = zero_eps(cfg)
    g = jax.grad(f)(eps0)
    rng = np.random.default_rng(0)
    for _ in range(4):
        l = int(rng.integers(cfg.n_layer))
        h = int(rng.integers(cfg.n_head))
        s = int(rng.integers(cfg.seq_len))
        d = int(rng.integers(cfg.d_model))
        delta = 1e-3
        for key in ("eps_q", "eps_k", "eps_v"):
            ep = {k: v for k, v in eps0.items()}
            ep[key] = eps0[key].at[l, 0, h, s, d].set(delta)
            em = {k: v for k, v in eps0.items()}
            em[key] = eps0[key].at[l, 0, h, s, d].set(-delta)
            fd = (f(ep) - f(em)) / (2 * delta)
            an = g[key][l, 0, h, s, d]
            # f32 central differences carry ~1e-4 cancellation noise on a
            # metric of O(1); the analytic side is exact AD.
            np.testing.assert_allclose(float(fd), float(an), rtol=0.15, atol=5e-4)


def test_gates_all_ones_is_identity():
    cfg = CFG
    params, (clean, corrupt, pos, ans, dis) = setup(cfg)
    ref_probs = jnp.full((cfg.batch, cfg.vocab), 1.0 / cfg.vocab)
    _, caches = forward_full(cfg, params, corrupt, collect=True)
    gates = jnp.ones((cfg.n_nodes,))
    m = forward_with_gates(cfg, params, clean, pos, ans, dis, ref_probs,
                           jnp.float32(1.0), gates, corrupt_caches=caches)
    logits = forward_full(cfg, params, clean)
    want = combined_metric(logits, pos, ans, dis, ref_probs, jnp.float32(1.0))
    np.testing.assert_allclose(float(m), float(want), rtol=1e-5)


def test_gates_all_zero_is_corrupt_run():
    """With every gate at 0 and corrupt caches attached, node outputs are
    the corrupted ones — the metric must equal the corrupted forward's."""
    cfg = CFG_AO
    params, (clean, corrupt, pos, ans, dis) = setup(cfg)
    ref_probs = jnp.full((cfg.batch, cfg.vocab), 1.0 / cfg.vocab)
    _, caches = forward_full(cfg, params, corrupt, collect=True)
    gates = jnp.zeros((cfg.n_nodes,))
    m = forward_with_gates(cfg, params, clean, pos, ans, dis, ref_probs,
                           jnp.float32(1.0), gates, corrupt_caches=caches)
    # corrupted node outputs + clean embed anchor == patching every head
    emb_c = embed(clean, params["wte"], params["wpe"])
    resid = emb_c
    for l in range(cfg.n_layer):
        resid = resid + jnp.sum(caches[f"attn{l}"], axis=1)
    logits = unembed(resid, params["lnf_g"], params["wu"])
    want = combined_metric(logits, pos, ans, dis, ref_probs, jnp.float32(1.0))
    np.testing.assert_allclose(float(m), float(want), rtol=1e-4, atol=1e-5)


def test_edge_mask_all_ones_equals_clean():
    cfg = CFG_AO
    params, (clean, corrupt, pos, ans, dis) = setup(cfg)
    N, L, H = cfg.n_nodes, cfg.n_layer, cfg.n_head
    _, cc = forward_full(cfg, params, corrupt, collect=True)
    corrupt_nodes = [cc["embed"]]
    for l in range(L):
        for h in range(H):
            corrupt_nodes.append(cc[f"attn{l}"][:, h])
    corrupt_nodes = jnp.stack(corrupt_nodes)
    masks = {
        "mq": jnp.ones((L, H, N)), "mk": jnp.ones((L, H, N)),
        "mv": jnp.ones((L, H, N)), "mm": jnp.ones((L, N)),
        "mf": jnp.ones((N,)),
    }
    logits = forward_edge_masked(cfg, params, clean, masks, corrupt_nodes)
    want = forward_full(cfg, params, clean)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_metrics():
    """KL of identical distributions is 0; logit-diff is linear in logits."""
    B, S, V = 2, 4, 8
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(B, S, V)).astype(np.float32))
    pos = np.zeros((B, S), np.float32)
    pos[:, 2] = 1
    pos = jnp.asarray(pos)
    at = jnp.einsum("bs,bsv->bv", pos, logits)
    probs = jax.nn.softmax(at, axis=-1)
    from compile.model import metric_kl, metric_logit_diff

    kl = metric_kl(logits, pos, probs)
    assert abs(float(kl)) < 1e-6
    ans = jnp.asarray(np.eye(V, dtype=np.float32)[None, 0].repeat(B, 0))
    dis = jnp.asarray(np.eye(V, dtype=np.float32)[None, 1].repeat(B, 0))
    ld = metric_logit_diff(logits, pos, ans, dis)
    want = float(jnp.mean(at[:, 0] - at[:, 1]))
    np.testing.assert_allclose(float(ld), want, rtol=1e-6)
