"""Fake-quant lattice properties — the numerics that make the whole paper
tick (section 2: numerical underflow + mantissa loss)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantize
from compile.kernels.fq import fq_pallas

PRESETS = ["fp8_e4m3", "fp8_e5m2", "fp4_e2m1", "bf16", "fp16"]


def fq(x, preset):
    return np.asarray(
        quantize.fake_quant_qp(jnp.asarray(x, jnp.float32), quantize.qp_array(preset))
    )


_LIM = 3.0000000054977558e38
finite_f32 = st.floats(
    min_value=-_LIM, max_value=_LIM, allow_nan=False, allow_infinity=False, width=32
)


@settings(max_examples=300, deadline=None)
@given(finite_f32, st.sampled_from(PRESETS))
def test_idempotent(x, preset):
    """Quantizing a quantized value is a fixed point."""
    once = fq(np.array([x]), preset)
    twice = fq(once, preset)
    assert once[0] == twice[0]


@settings(max_examples=200, deadline=None)
@given(st.lists(finite_f32, min_size=2, max_size=50), st.sampled_from(PRESETS))
def test_monotonic(xs, preset):
    """x <= y implies fq(x) <= fq(y) (rounding preserves order)."""
    xs = np.sort(np.asarray(xs, np.float32))
    ys = fq(xs, preset)
    assert np.all(np.diff(ys) >= 0)


@settings(max_examples=300, deadline=None)
@given(finite_f32, st.sampled_from(PRESETS))
def test_representable(x, preset):
    """fq(x) * 2^(mbits - E) is an integer (value lies on the grid)."""
    mbits, emin, maxv = quantize.PRESETS[preset]
    y = float(fq(np.array([x]), preset)[0])
    if y == 0.0 or abs(y) >= maxv:
        return
    if abs(y) < 2.0**-126:
        # below the quantum floor the implementation is FTZ (see
        # quantize.fake_quant docs); XLA's own subnormal handling may pass
        # the input through — not a lattice point, by design
        return
    e = max(np.floor(np.log2(abs(y))), emin)
    scaled = y / 2.0 ** (e - mbits)
    assert abs(scaled - round(scaled)) < 1e-6


@settings(max_examples=300, deadline=None)
@given(finite_f32, st.sampled_from(PRESETS))
def test_relative_error_bound(x, preset):
    """|fq(x) - x| <= quantum/2 within the normal range."""
    mbits, emin, maxv = quantize.PRESETS[preset]
    y = float(fq(np.array([x]), preset)[0])
    if x == 0 or abs(x) > maxv or np.floor(np.log2(abs(x))) < emin:
        return
    quantum = 2.0 ** (np.floor(np.log2(abs(x))) - mbits)
    assert abs(y - x) <= quantum / 2 + 1e-30


def test_e4m3_known_values():
    """Anchor values of FP8_E4M3 (Kuzmin et al.): max 448, quantum at
    binade [1,2) is 2^-3, subnormal quantum 2^-9."""
    cases = {
        448.0: 448.0,
        1000.0: 448.0,  # saturating clamp
        1.0: 1.0,
        1.0625: 1.0,  # 1 + 2^-4 rounds-to-even down
        1.1875: 1.25,  # rounds up to 1.25? no: grid 1.0,1.125,1.25 -> 1.1875 ties-to-even -> 1.25? see below
        2.0**-9: 2.0**-9,  # smallest subnormal
        2.0**-10: 0.0,  # below subnormal quantum -> underflow to 0
        0.0: 0.0,
    }
    # 1.1875 is exactly between 1.125 and 1.25 -> ties-to-even picks 1.25
    # (1.25 = 10 * 2^-3, even multiple).
    for x, want in cases.items():
        got = float(fq(np.array([x]), "fp8_e4m3")[0])
        assert got == want, (x, got, want)


def test_numerical_underflow_paper_s2():
    """Paper section 2: contrasts below the quantization step vanish.
    Around 1.0 the E4M3 step is 2^-3 = 0.125; a 0.05 perturbation is
    invisible after quantization."""
    a = np.float32(1.0)
    b = np.float32(1.05)
    assert float(fq(np.array([a]), "fp8_e4m3")[0]) == float(
        fq(np.array([b]), "fp8_e4m3")[0]
    )


def test_mantissa_loss_paper_s2():
    """Paper section 2: adding values with exponent gap >= 4 under E4M3
    (3 mantissa bits) loses the small addend entirely: fq(big + small)
    == big."""
    big = np.float32(8.0)
    small = np.float32(0.4)  # gap: exp(8)=3, exp(0.4)=-2 -> gap 5
    s = fq(np.array([big + small]), "fp8_e4m3")
    assert float(s[0]) == 8.0


@settings(max_examples=50, deadline=None)
@given(st.lists(finite_f32, min_size=1, max_size=64), st.sampled_from(PRESETS))
def test_pallas_kernel_bit_exact(xs, preset):
    """The Pallas fq kernel and the jnp oracle agree bit-for-bit."""
    x = np.asarray(xs, np.float32)
    ref = fq(x, preset)
    ker = np.asarray(fq_pallas(jnp.asarray(x), quantize.qp_array(preset)))
    assert np.array_equal(ref, ker, equal_nan=True)


def test_fp32_passthrough():
    x = np.asarray([1.2345678e-20, 3.14159, -1e30], np.float32)
    y = np.asarray(
        quantize.fake_quant_qp(jnp.asarray(x), quantize.qp_array("fp32"))
    )
    assert np.array_equal(x, y)


def test_rtn_int_quant_eq23():
    """Paper Eq. 23: delta = max|w| / 2^(N-1); outputs are integer
    multiples of delta."""
    w = np.asarray([-1.0, -0.4, 0.0, 0.3, 0.8], np.float32)
    q = np.asarray(quantize.rtn_int_quant(jnp.asarray(w), 4))
    delta = 1.0 / 8.0
    assert np.allclose(q / delta, np.round(q / delta))
    assert np.max(np.abs(q - w)) <= delta / 2
