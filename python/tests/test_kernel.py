"""L1 kernel vs pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes and precision assignments; every Pallas kernel
must agree with its ref.py oracle. Tolerances are tight (the kernels do the
same f32 math, modulo reduction order inside dot)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantize
from compile.kernels import ref
from compile.kernels.attn_core import attn_core_pallas
from compile.kernels.mixed_attn import project_heads_pallas

PRESETS = ["fp32", "fp8_e4m3", "bf16", "fp4_e2m1"]


def rand(rng, *shape):
    return rng.normal(0, 1, size=shape).astype(np.float32)


def qp_rows(rng, h):
    names = [PRESETS[i] for i in rng.integers(0, len(PRESETS), size=h)]
    return np.stack([np.asarray(quantize.PRESETS[n], np.float32) for n in names])


shapes = st.tuples(
    st.integers(1, 3),  # B
    st.integers(1, 4),  # H
    st.integers(2, 12),  # S
    st.integers(4, 24),  # D
    st.integers(2, 8),  # K
)


@settings(max_examples=25, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1))
def test_project_heads_matches_ref(shape, seed):
    B, H, S, D, K = shape
    rng = np.random.default_rng(seed)
    x = rand(rng, B, H, S, D)
    g = rand(rng, D)
    w = rand(rng, H, D, K) * 0.3
    b = rand(rng, H, K) * 0.1
    qp = qp_rows(rng, H)
    want = np.asarray(ref.project_heads(jnp.asarray(x), jnp.asarray(g),
                                        jnp.asarray(w), jnp.asarray(b),
                                        jnp.asarray(qp)))
    got = np.asarray(project_heads_pallas(jnp.asarray(x), jnp.asarray(g),
                                          jnp.asarray(w), jnp.asarray(b),
                                          jnp.asarray(qp)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(shapes, st.integers(0, 2**31 - 1))
def test_attn_core_matches_ref(shape, seed):
    B, H, S, _, K = shape
    rng = np.random.default_rng(seed)
    q = rand(rng, B, H, S, K)
    k = rand(rng, B, H, S, K)
    v = rand(rng, B, H, S, K)
    qp = qp_rows(rng, H)
    want = np.asarray(ref.attn_core(*(jnp.asarray(a) for a in (q, k, v)),
                                    jnp.asarray(qp)))
    got = np.asarray(attn_core_pallas(*(jnp.asarray(a) for a in (q, k, v)),
                                      jnp.asarray(qp)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attn_core_is_causal():
    """Changing a future token must not affect earlier positions."""
    rng = np.random.default_rng(0)
    B, H, S, K = 1, 2, 8, 4
    q, k, v = (rand(rng, B, H, S, K) for _ in range(3))
    qp = np.tile(np.asarray(quantize.FP32, np.float32), (H, 1))
    z1 = np.asarray(attn_core_pallas(*map(jnp.asarray, (q, k, v)), jnp.asarray(qp)))
    k2, v2 = k.copy(), v.copy()
    k2[:, :, -1] += 10.0
    v2[:, :, -1] -= 5.0
    z2 = np.asarray(attn_core_pallas(*map(jnp.asarray, (q, k2, v2)), jnp.asarray(qp)))
    np.testing.assert_allclose(z1[:, :, :-1], z2[:, :, :-1], rtol=1e-6)
    assert not np.allclose(z1[:, :, -1], z2[:, :, -1])


def test_mixed_assembly_equivalence():
    """Paper Eq. 7-10: two-phase (FP8-all + FP32-target, then select) equals
    single-pass per-head precision — the identity PAHQ's kernel fusion
    relies on (DESIGN.md section 2)."""
    rng = np.random.default_rng(7)
    B, H, S, D, K = 2, 4, 6, 16, 8
    x = rand(rng, B, H, S, D)
    g, w, b = rand(rng, D), rand(rng, H, D, K) * 0.3, rand(rng, H, K) * 0.1
    target = 2
    qp_mixed = np.tile(np.asarray(quantize.FP8_E4M3, np.float32), (H, 1))
    qp_mixed[target] = quantize.FP32
    mixed = np.asarray(ref.project_heads(*map(jnp.asarray, (x, g, w, b, qp_mixed))))

    qp8 = np.tile(np.asarray(quantize.FP8_E4M3, np.float32), (H, 1))
    qp32 = np.tile(np.asarray(quantize.FP32, np.float32), (H, 1))
    all8 = np.asarray(ref.project_heads(*map(jnp.asarray, (x, g, w, b, qp8))))
    all32 = np.asarray(ref.project_heads(*map(jnp.asarray, (x, g, w, b, qp32))))
    two_phase = all8.copy()
    two_phase[:, target] = all32[:, target]  # MixedAssembly (Eq. 9)
    np.testing.assert_array_equal(mixed, two_phase)
