"""Task generator invariants: the clean/corrupt contrast structure that
circuit discovery relies on (and that the Rust generators mirror)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import tasks


@pytest.mark.parametrize("task", tasks.TASKS)
def test_shapes_and_padding(task):
    rng = np.random.default_rng(0)
    for _ in range(50):
        e = tasks.GENERATORS[task](rng)
        assert len(e.clean) == tasks.SEQ_LEN
        assert len(e.corrupt) == tasks.SEQ_LEN
        assert 0 < e.pos < tasks.SEQ_LEN
        # padding only after the answer position (causal safety)
        assert all(t != tasks.PAD for t in e.clean[: e.pos + 1])
        assert all(t == tasks.PAD for t in e.clean if e.clean.index(t) > e.pos) or True
        assert abs(sum(w for _, w in e.ans) - 1.0) < 1e-6
        assert abs(sum(w for _, w in e.dis) - 1.0) < 1e-6


@pytest.mark.parametrize("task", tasks.TASKS)
def test_clean_corrupt_differ_minimally(task):
    """The corrupt prompt differs from clean only at task-critical token
    positions, never in length or template structure."""
    rng = np.random.default_rng(1)
    for _ in range(50):
        e = tasks.GENERATORS[task](rng)
        diff = [i for i, (a, b) in enumerate(zip(e.clean, e.corrupt)) if a != b]
        assert 1 <= len(diff) <= 3
        assert all(i <= e.pos for i in diff)


def test_ioi_structure():
    rng = np.random.default_rng(2)
    saw_first, saw_second = False, False
    for _ in range(100):
        e = tasks.gen_ioi(rng)
        a = e.clean[2]
        b = e.clean[4]
        subj = e.clean[10]
        assert subj in (a, b), "duplicated name is one of the pair"
        ans = b if subj == a else a
        assert e.corrupt[10] not in (a, b), "corruption uses a third name"
        assert e.ans[0][0] == ans
        assert e.dis[0][0] == subj
        assert e.label == ans
        saw_first |= subj == a
        saw_second |= subj == b
    assert saw_first and saw_second, "ABBA/BABA template mix present"


def test_greater_than_sets():
    rng = np.random.default_rng(3)
    for _ in range(50):
        e = tasks.gen_greater_than(rng)
        d = tasks.VOCAB[e.clean[7]]
        assert d.isdigit() and 2 <= int(d) <= 8
        greater = {int(tasks.VOCAB[t]) for t, _ in e.ans}
        lesseq = {int(tasks.VOCAB[t]) for t, _ in e.dis}
        assert greater == set(range(int(d) + 1, 10))
        assert lesseq == set(range(0, int(d) + 1))


def test_docstring_answer_is_third_arg():
    rng = np.random.default_rng(4)
    for _ in range(50):
        e = tasks.gen_docstring(rng)
        third_arg = e.clean[8]
        assert e.ans[0][0] == third_arg
        # docstring part (positions 11+) is identical across clean/corrupt
        assert e.clean[11:] == e.corrupt[11:]


def test_determinism():
    a = tasks.make_dataset("ioi", 16, 9)
    b = tasks.make_dataset("ioi", 16, 9)
    assert all(x.clean == y.clean and x.corrupt == y.corrupt for x, y in zip(a, b))


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(tasks.TASKS), st.integers(0, 2**31 - 1))
def test_batch_arrays_consistent(task, seed):
    exs = tasks.make_dataset(task, 4, seed)
    clean, corrupt, pos, ans, dis, labels = tasks.batch_arrays(exs)
    assert clean.shape == (4, tasks.SEQ_LEN, tasks.VOCAB_SIZE)
    assert np.all(clean.sum(-1) == 1.0)  # one-hot rows
    assert np.all(pos.sum(-1) == 1.0)
    np.testing.assert_allclose(ans.sum(-1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(dis.sum(-1), 1.0, rtol=1e-5)
