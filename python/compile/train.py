"""Build-time training of the tiny task models.

The paper analyses pretrained HuggingFace checkpoints (GPT-2, attn-4l,
redwood-2l); offline we train the same shape families from scratch on the
synthetic tasks (DESIGN.md section 1). Training is deterministic (seeded),
runs on CPU JAX in seconds-to-minutes, and happens exactly once inside
``make artifacts`` — python never touches the request path.

Each base model is trained *jointly* on all three tasks (as GPT-2 "knows"
all three paper tasks); the scale-series models (gpt2m/l/xl-sim) train on
IOI only, which is all appendix C evaluates. Loss is cross-entropy on the
answer token at the answer position — this keeps the learned circuit
crisply tied to the task contrast, which is what patching experiments need.

The optimizer is a self-contained Adam (no optax dependency).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import tasks
from .model import ModelConfig, forward_full, init_params

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def _ce_loss(cfg, params, onehot, pos, labels):
    logits = forward_full(cfg, params, onehot)  # [B,S,V]
    at_pos = jnp.einsum("bs,bsv->bv", pos, logits)
    logp = jax.nn.log_softmax(at_pos, axis=-1)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def _batch(task_names, batch, rng):
    """Sample a mixed-task training batch."""
    exs = []
    for i in range(batch):
        t = task_names[int(rng.integers(len(task_names)))]
        exs.append(tasks.GENERATORS[t](rng))
    clean, _, pos, _, _, labels = tasks.batch_arrays(exs)
    return jnp.asarray(clean), jnp.asarray(pos), jnp.asarray(labels)


def train_model(
    cfg: ModelConfig,
    task_names: list[str],
    steps: int = 1500,
    batch: int = 32,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 500,
):
    """Train and return (params, final train accuracy per task)."""
    rng = np.random.default_rng(seed)
    params = init_params(cfg, seed)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)

    loss_grad = jax.jit(jax.value_and_grad(lambda p, x, q, y: _ce_loss(cfg, p, x, q, y)))

    tmap = jax.tree_util.tree_map

    @jax.jit
    def adam(params, m, v, grads, t):
        lr_t = lr * jnp.sqrt(1 - ADAM_B2**t) / (1 - ADAM_B1**t)
        m2 = tmap(lambda mm, g: ADAM_B1 * mm + (1 - ADAM_B1) * g, m, grads)
        v2 = tmap(lambda vv, g: ADAM_B2 * vv + (1 - ADAM_B2) * g * g, v, grads)
        p2 = tmap(lambda p, mm, vv: p - lr_t * mm / (jnp.sqrt(vv) + ADAM_EPS),
                  params, m2, v2)
        return p2, m2, v2

    t0 = time.time()
    for step in range(1, steps + 1):
        x, q, y = _batch(task_names, batch, rng)
        loss, grads = loss_grad(params, x, q, y)
        params, m, v = adam(params, m, v, grads, step)
        if step % log_every == 0 or step == steps:
            print(f"  [{cfg.name}] step {step}/{steps} loss={float(loss):.4f} "
                  f"({time.time() - t0:.1f}s)")

    accs = {t: eval_accuracy(cfg, params, t, seed=seed + 1) for t in task_names}
    return params, accs


def eval_accuracy(cfg: ModelConfig, params, task: str, n: int = 128, seed: int = 1):
    """Top-1 accuracy of the answer token on held-out samples.

    For Greater-Than, 'correct' means the argmax digit is strictly greater
    than the start digit (any member of the answer set)."""
    rng = np.random.default_rng(seed)
    exs = [tasks.GENERATORS[task](rng) for _ in range(n)]
    clean, _, pos, ans, _, labels = tasks.batch_arrays(exs)
    logits = forward_full(cfg, params, jnp.asarray(clean))
    at_pos = jnp.einsum("bs,bsv->bv", jnp.asarray(pos), logits)
    pred = np.asarray(jnp.argmax(at_pos, axis=-1))
    ok = np.array([ans[i, pred[i]] > 0 for i in range(n)])
    return float(ok.mean())
