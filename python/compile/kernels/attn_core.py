"""Pallas kernel for the per-head causal attention core.

One grid step per (batch, head): scores = q k^T / sqrt(K) with a causal
mask, a numerically-stable softmax, z = probs @ v, and a per-head
fake-quant of z. Scores/softmax run at full precision, matching the paper's
Eq. 10 (activations are unified to FP32 for the attention computation after
MixedAssembly); only the head's output re-enters the quantized lattice.

TPU mapping: q/k/v tiles for one head ([S, K] each, ~5 KiB at the largest
config here) live in VMEM; scores [S, S] stay in VMEM registers; both
matmuls hit the MXU. The causal mask is built with ``broadcasted_iota``
(no host-side constant traffic).

Oracle: ``ref.attn_core``. interpret=True (see mixed_attn.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..quantize import fake_quant


def _attn_kernel(q_ref, k_ref, v_ref, qp_ref, o_ref):
    # One grid step per head, whole batch per tile (see mixed_attn.py for
    # the MXU / interpret-mode trip-count rationale).
    q = q_ref[:, 0]  # [B, S, K]
    k = k_ref[:, 0]
    v = v_ref[:, 0]
    _, S, K = q.shape
    scores = jnp.einsum("bqk,bsk->bqs", q, k) / jnp.sqrt(jnp.float32(K))
    rows = lax.broadcasted_iota(jnp.int32, (S, S), 0)
    cols = lax.broadcasted_iota(jnp.int32, (S, S), 1)
    scores = jnp.where((cols <= rows)[None], scores, -1e9)
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    z = jnp.einsum("bqs,bsk->bqk", p, v)
    qp = qp_ref[0]
    o_ref[:, 0] = fake_quant(z, qp[0], qp[1], qp[2])


def attn_core_pallas(q, k, v, qp):
    """Causal attention core; signature matches ``ref.attn_core``.

    q,k,v [B,H,S,K], qp [H,3] -> z [B,H,S,K].
    """
    B, H, S, K = q.shape
    spec = pl.BlockSpec((B, 1, S, K), lambda j: (0, j, 0, 0))
    return pl.pallas_call(
        _attn_kernel,
        grid=(H,),
        in_specs=[spec, spec, spec, pl.BlockSpec((1, 3), lambda j: (j, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, S, K), jnp.float32),
        interpret=True,
    )(
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        jnp.asarray(qp, jnp.float32),
    )
