"""Pallas kernel for PAHQ's mixed-precision per-head projection (paper
Eq. 7-10).

The paper's CUDA implementation runs two GEMMs per component — an FP8 GEMM
over all heads (Eq. 7) and an FP32 GEMM for the target head h* (Eq. 8) —
then selects per head (MixedAssembly, Eq. 9) and casts everything to FP32
(Eq. 10). On the value lattice those three steps are identical to computing
*each head once at its assigned precision*, so the TPU rethink fuses them:

- grid over (batch, head): each grid step owns one head's [S, D] residual
  tile in VMEM, its [D, K] weight tile, and its (mbits, emin, maxv) row;
- the kernel computes rmsnorm -> MXU matmul -> bias -> fake-quant at the
  head's own precision, writing the already-"assembled" FP32 tile;
- head h* simply carries the passthrough qp row, so the high-precision path
  and the select of Eq. 9 cost nothing extra.

VMEM per grid step (f32): B*S*D + D*K + B*S*K + K + 3 floats. For the
largest model here (B=16, S=20, D=160, K=20) that is ~230 KiB — far under
the ~16 MiB VMEM budget, leaving room for double-buffering the H-grid
(DESIGN.md section 8). Folding the batch into the tile keeps the MXU's M
dimension at B*S=320 rows instead of 20.

Correctness oracle: ``ref.project_heads``. interpret=True everywhere (CPU
PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..quantize import fake_quant
from .ref import RMS_EPS


def _project_kernel(x_ref, g_ref, w_ref, b_ref, qp_ref, o_ref):
    # Blocks: x [B,1,S,D], g [D], w [1,D,K], b [1,K], qp [1,3], o [B,1,S,K].
    # One grid step per head; the whole batch is processed as a single
    # MXU-friendly [B*S, D] x [D, K] tile (the batch axis folds into the
    # GEMM's M dimension — much better MXU occupancy than per-example
    # tiles, and under interpret=True it keeps the XLA while-loop trip
    # count at H instead of B*H, which dominates CPU wall time).
    x = x_ref[:, 0]  # [B, S, D]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    xn = x * lax.rsqrt(ms + RMS_EPS) * g_ref[...]
    y = jnp.einsum("bsd,dk->bsk", xn, w_ref[0]) + b_ref[0][None, None, :]
    qp = qp_ref[0]
    o_ref[:, 0] = fake_quant(y, qp[0], qp[1], qp[2])


def project_heads_pallas(x, ln_g, w, b, qp):
    """Mixed-precision per-head projection; signature matches
    ``ref.project_heads``.

    x [B,H,S,D], ln_g [D], w [H,D,K], b [H,K], qp [H,3] -> [B,H,S,K].
    """
    B, H, S, D = x.shape
    K = w.shape[-1]
    return pl.pallas_call(
        _project_kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((B, 1, S, D), lambda j: (0, j, 0, 0)),
            pl.BlockSpec((D,), lambda j: (0,)),
            pl.BlockSpec((1, D, K), lambda j: (j, 0, 0)),
            pl.BlockSpec((1, K), lambda j: (j, 0)),
            pl.BlockSpec((1, 3), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((B, 1, S, K), lambda j: (0, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, K), jnp.float32),
        interpret=True,
    )(
        x.astype(jnp.float32),
        jnp.asarray(ln_g, jnp.float32),
        jnp.asarray(w, jnp.float32),
        jnp.asarray(b, jnp.float32),
        jnp.asarray(qp, jnp.float32),
    )
