"""L1 Pallas kernels (mixed_attn, attn_core, fq) and their pure-jnp oracle (ref)."""
