"""Pallas elementwise fake-quantization kernel.

The simplest of the three L1 kernels: round a tile to the (mbits, emin,
maxv) lattice. Used standalone for weight/activation re-quantization inside
the L2 graph and as the bit-exactness anchor between python and Rust
(``python/tests/test_fq.py`` cross-checks this kernel, the jnp oracle and
vector files consumed by the Rust quant tests).

TPU mapping (DESIGN.md section 2): elementwise on VPU lanes; the tile is a
single VMEM block per grid step. Run with ``interpret=True`` here — the CPU
PJRT client cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..quantize import fake_quant


def _fq_kernel(x_ref, qp_ref, o_ref):
    qp = qp_ref[...]
    o_ref[...] = fake_quant(x_ref[...], qp[0], qp[1], qp[2])


def fq_pallas(x, qp):
    """Fake-quantize ``x`` (any shape) with a single (3,) qp vector."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    out = pl.pallas_call(
        _fq_kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat.astype(jnp.float32), jnp.asarray(qp, jnp.float32))
    return out.reshape(orig_shape)
