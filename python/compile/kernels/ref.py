"""Pure-jnp oracles for the Pallas kernels (L1 correctness reference).

Every kernel in this package has a reference implementation here with the
same signature; ``python/tests/test_kernel.py`` asserts allclose (and for
the fake-quant lattice, bit-exact equality) between kernel and oracle under
hypothesis-driven shape/dtype sweeps. The AOT gradient artifacts
(grads/gate/edge-mask HLOs) are built on these reference paths because
``pallas_call`` is not differentiable; the forward inference artifacts use
the Pallas kernels.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from ..quantize import fake_quant_qp

RMS_EPS = 1e-6


def rmsnorm(x, g):
    """RMS-normalize over the last axis and scale by gain ``g`` [D]."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * lax.rsqrt(ms + RMS_EPS) * g


def project_heads(x, ln_g, w, b, qp):
    """Per-head normalized projection with per-head fake-quant.

    x    : [B, H, S, D]   per-head assembled residual inputs
    ln_g : [D]            shared layer-norm gain
    w    : [H, D, K]      per-head projection
    b    : [H, K]
    qp   : [H, 3]         per-head (mbits, emin, maxv)
    ->     [B, H, S, K]

    This is the oracle for the paper's two-phase mixed-precision projection
    (Eq. 7-9): computing FP8 for all heads and FP32 for the target head and
    then selecting (Eq. 9) is value-identical to computing each head at its
    assigned precision, which is what the parametric ``qp`` does.
    """
    xn = rmsnorm(x, ln_g)
    y = jnp.einsum("bhsd,hdk->bhsk", xn, w) + b[None, :, None, :]
    return fake_quant_qp(y, qp[None])  # qp [1,H,3] broadcasts over B


def attn_core(q, k, v, qp):
    """Per-head causal attention core with fake-quantized output.

    q,k,v : [B, H, S, K]; qp : [H, 3]  ->  z [B, H, S, K]

    Scores and softmax run at full precision (the paper unifies activations
    to FP32 for the attention computation after MixedAssembly, Eq. 10); the
    per-head output z is quantized at the head's precision.
    """
    kdim = q.shape[-1]
    scores = jnp.einsum("bhqk,bhsk->bhqs", q, k) / jnp.sqrt(jnp.float32(kdim))
    s = q.shape[2]
    causal = jnp.tril(jnp.ones((s, s), jnp.float32))
    scores = jnp.where(causal[None, None] > 0, scores, -1e9)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    z = jnp.einsum("bhqs,bhsk->bhqk", probs, v)
    return fake_quant_qp(z, qp[None])


def fq_ref(x, qp):
    """Elementwise fake-quant oracle (matches kernels/fq.py)."""
    return fake_quant_qp(x, qp)
