"""Parametric fake-quantization (quantize -> dequantize) used to emulate
low-precision formats on an FP32 substrate.

The paper runs native FP8_E4M3 on H20 tensor cores; this reproduction runs on
CPU PJRT, so low precision is emulated *bit-exactly on the value lattice*:
a fake-quantized tensor takes exactly the values representable in the target
format (round-to-nearest-even, saturating clamp). Underflow and mantissa-loss
(paper section 2) are properties of that lattice, so they reproduce exactly.

A format is a triple ``(mbits, emin, maxv)``:

- ``mbits``  : number of mantissa bits (3 for E4M3, 1 for E2M1, 7 for bf16,
               10 for fp16, 23 => passthrough / FP32 sentinel).
- ``emin``   : minimum unbiased exponent of a *normal* number. Values with
               floor(log2|x|) < emin quantize on the subnormal grid
               2**(emin - mbits).
- ``maxv``   : saturation bound (e.g. 448 for E4M3, 6 for E2M1).

The same triple is interpreted by the Rust side (``rust/src/quant``); the
python and Rust implementations are property-tested for bit-exact agreement
(``python/tests/test_fq.py`` writes vectors consumed by
``rust/src/quant/tests``).

Everything here is plain jnp (frexp/exp2/round/clip/where), so it lowers to
basic HLO that xla_extension 0.5.1's text parser accepts.
"""

from __future__ import annotations

import jax.numpy as jnp

# (mbits, emin, maxv) presets. Keep in sync with rust/src/quant/mod.rs.
FP32 = (99.0, -126.0, 3.4e38)  # passthrough sentinel (mbits >= 23)
FP16 = (10.0, -14.0, 65504.0)
BF16 = (7.0, -126.0, 3.39e38)
FP8_E4M3 = (3.0, -6.0, 448.0)
FP8_E5M2 = (2.0, -14.0, 57344.0)
FP4_E2M1 = (1.0, 0.0, 6.0)

PRESETS = {
    "fp32": FP32,
    "fp16": FP16,
    "bf16": BF16,
    "fp8_e4m3": FP8_E4M3,
    "fp8_e5m2": FP8_E5M2,
    "fp4_e2m1": FP4_E2M1,
}


def qp_array(preset_or_triple):
    """Return a (3,) f32 array for a preset name or an (mbits, emin, maxv)
    triple, suitable as a runtime HLO input."""
    if isinstance(preset_or_triple, str):
        preset_or_triple = PRESETS[preset_or_triple]
    return jnp.asarray(preset_or_triple, dtype=jnp.float32)


def fake_quant(x, mbits, emin, maxv):
    """Round ``x`` to the nearest representable value of the format.

    ``mbits``/``emin``/``maxv`` may be scalars or arrays broadcastable
    against ``x`` (e.g. per-head parameters of shape [H, 1, 1] against
    activations [H, S, D]) — this is what lets a single AOT-lowered HLO
    serve every precision assignment PAHQ makes at runtime.

    Grid-point rounding (saturate-then-round):
      xc = clip(x, -maxv, maxv)         (saturate FIRST: keeps every
                                         intermediate finite, so behaviour
                                         is identical across jnp / Pallas /
                                         Rust — no inf-dependent paths)
      e = floor(log2|xc|)               (exact, via frexp)
      E = max(e, emin)                  (subnormal floor)
      q = 2**max(E - mbits, -126)       (quantum; built by *exponent bit
                                         manipulation*, not jnp.exp2 — XLA
                                         CPU's exp2 is an approximate
                                         transcendental and is not exact
                                         even at integer arguments. The
                                         -126 floor keeps q a normal f32;
                                         values whose quantum would be
                                         subnormal flush toward zero: FTZ
                                         semantics, mirrored bit-for-bit
                                         in Rust)
      y = round_ties_even(xc / q) * q   (xc/q and *q are exact: q is a
                                         power of two)
      y = clip(y, -maxv, maxv)          (bf16's maxv is off-grid; re-clamp)

    round-to-nearest-even matches IEEE default rounding and Rust's
    ``f32::round_ties_even``. ``mbits >= 23`` passes through unchanged.

    Note on the upper binade edge: round-up across a binade (e.g. E4M3
    447.99 -> 448) lands on an even multiple of the lower binade's quantum,
    which is also representable in the upper binade, so the one-binade
    quantum is still correct at the boundary.
    """
    from jax import lax

    x = jnp.asarray(x, jnp.float32)
    xc = jnp.clip(x, -maxv, maxv)
    ax = jnp.abs(xc)
    # frexp: ax = m * 2**e with m in [0.5, 1)  =>  floor(log2 ax) = e - 1.
    _, e = jnp.frexp(ax)
    e = e.astype(jnp.float32) - 1.0
    e = jnp.maximum(e, emin)
    q = _pow2(jnp.maximum(e - mbits, -126.0))
    y = jnp.round(xc / q) * q  # jnp.round is round-half-to-even
    y = jnp.clip(y, -maxv, maxv)
    # Subnormal inputs (biased exponent 0, detected bitwise — XLA CPU's
    # FTZ makes value comparisons unreliable down here) flush to a
    # sign-preserving zero; zeros pass through. Mirrored exactly in Rust.
    subnormal = (lax.bitcast_convert_type(ax, jnp.int32) >> 23) == 0
    y = jnp.where(subnormal, x * 0.0, y)
    return jnp.where(mbits >= 23.0, x, y)


def _pow2(expo):
    """Exact 2**expo for integer-valued expo in [-126, 127], by placing the
    biased exponent bits directly (bitcast) — jnp.exp2/ldexp route through
    an approximate transcendental on XLA CPU."""
    from jax import lax

    expo = jnp.clip(expo, -126.0, 127.0)
    bits = (expo.astype(jnp.int32) + 127) << 23
    return lax.bitcast_convert_type(bits, jnp.float32)


def fake_quant_qp(x, qp):
    """``fake_quant`` with a packed (..., 3) parameter tensor.

    ``qp[..., 0] = mbits``, ``qp[..., 1] = emin``, ``qp[..., 2] = maxv``.
    The leading axes of ``qp`` must broadcast against ``x`` after appending
    singleton axes: e.g. qp [H, 3] applies row h to x[h, ...].
    """
    qp = jnp.asarray(qp, jnp.float32)
    extra = x.ndim - (qp.ndim - 1)
    shape = qp.shape[:-1] + (1,) * extra
    mbits = qp[..., 0].reshape(shape)
    emin = qp[..., 1].reshape(shape)
    maxv = qp[..., 2].reshape(shape)
    return fake_quant(x, mbits, emin, maxv)


def rtn_int_quant(w, nbits):
    """Integer round-to-nearest quantization, paper Eq. (23):
    Q(w) = delta * round(w / delta), delta = max|w| / 2**(N-1).

    Used for the RTN weight-quantization comparison in the quantization
    strategy appendix; the main RTN-Q baseline uses FP8 fake-quant to match
    the paper's FP8_E4M3 setting.
    """
    w = jnp.asarray(w, jnp.float32)
    delta = jnp.max(jnp.abs(w)) / (2.0 ** (nbits - 1))
    delta = jnp.where(delta == 0.0, 1.0, delta)
    return delta * jnp.round(w / delta)
