"""Synthetic circuit-discovery tasks mirroring the causal template structure
of the paper's three benchmarks (IOI, Greater-Than, Docstring).

The originals depend on GPT-2's tokenizer and pretraining corpus, neither of
which is available offline. What circuit discovery actually consumes is the
*clean/corrupt contrast*: a pair of prompts identical except for the tokens
that carry the task-critical information, plus a metric that reads the
behaviour off the logits. These generators preserve exactly that structure:

- IOI        : duplicate-name indirect-object identification (ABB -> ABC
               corruption, as in Wang et al. 2022).
- GreaterThan: two-digit year continuation; the model must place probability
               mass on digits strictly greater than the start digit
               (corruption resets the start digit to 0, as the paper's "01").
- Docstring  : argument recall from a def-stub; the model must emit the next
               ":param" argument name (corruption scrambles the signature).

All tasks share one vocabulary and one padded sequence length so a single
set of AOT-lowered per-layer HLOs serves every task. The vocabulary and the
evaluation datasets are exported into the artifact manifest, and the Rust
side (`rust/src/tasks/`) re-implements the same generators against the same
vocab for workload-scaling benchmarks; `python/tests/test_tasks.py` checks
the two agree on the template structure.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

SEQ_LEN = 20

_NAMES = [f"name{i}" for i in range(8)]
_ARGS = [f"arg{i}" for i in range(8)]
_FUNCS = [f"fn{i}" for i in range(4)]
_DIGITS = [str(d) for d in range(10)]
_WORDS = [
    "when", "and", "went", "to", "the", "store", ",", "gave", "a", "gift",
    "war", "lasted", "from", "year", "17",
    "def", "(", ")", ":", "param",
]

VOCAB: list[str] = ["<pad>", "<bos>"] + _NAMES + _ARGS + _FUNCS + _DIGITS + _WORDS
TOK = {t: i for i, t in enumerate(VOCAB)}
VOCAB_SIZE = len(VOCAB)
PAD, BOS = TOK["<pad>"], TOK["<bos>"]


def _ids(*toks: str) -> list[int]:
    return [TOK[t] for t in toks]


@dataclasses.dataclass
class Example:
    """One clean/corrupt pair.

    ``ans``/``dis`` are sparse distributions over the vocabulary
    (list of (token_id, weight), weights summing to 1): the task metric is
    logit_diff = <logits[pos], ans> - <logits[pos], dis>. Single-token tasks
    use singleton distributions; Greater-Than uses uniform sets, which makes
    the metric the mean-logit gap between the "greater" and "not greater"
    digit sets (the ACDC paper's prob-mass metric in logit form).
    """

    clean: list[int]
    corrupt: list[int]
    pos: int  # answer position: predict token pos+1 from logits at pos
    ans: list[tuple[int, float]]
    dis: list[tuple[int, float]]
    label: int  # training target token at pos

    def padded(self, seq_len: int = SEQ_LEN) -> "Example":
        def pad(x):
            assert len(x) <= seq_len, (len(x), seq_len)
            return x + [PAD] * (seq_len - len(x))

        return dataclasses.replace(self, clean=pad(self.clean), corrupt=pad(self.corrupt))


def gen_ioi(rng: np.random.Generator) -> Example:
    """When <X> and <Y> went to the store , <S> gave a gift to -> other(S).

    <S> is the *duplicated* name — uniformly either <X> or <Y> (the ABBA /
    BABA template mix of Wang et al. 2022). Randomizing which first-clause
    name repeats is essential: with a fixed template the answer is
    position-predictable and the model never learns the duplication
    mechanism, leaving nothing for activation patching to find.

    Corruption (ABC): the duplicated occurrence is replaced by a third
    name <C>, destroying the signal identifying the indirect object.
    """
    a, b, c = rng.choice(len(_NAMES), size=3, replace=False)
    na, nb, nc = (TOK[_NAMES[i]] for i in (a, b, c))
    # subject = duplicated name; answer = the other (indirect object)
    subj, ans = (na, nb) if rng.integers(2) == 0 else (nb, na)
    head = _ids("<bos>", "when") + [na] + _ids("and") + [nb]
    mid = _ids("went", "to", "the", "store", ",")
    clean = head + mid + [subj] + _ids("gave", "a", "gift", "to")
    corrupt = head + mid + [nc] + _ids("gave", "a", "gift", "to")
    pos = len(clean) - 1
    return Example(clean, corrupt, pos, [(ans, 1.0)], [(subj, 1.0)], ans).padded()


def gen_greater_than(rng: np.random.Generator) -> Example:
    """the war lasted from year 17 <D> to year 17 -> digit > <D>.

    Clean start digit D in [2, 8]; corruption resets D to 0 (the paper's
    "01" corruption), removing the lower bound.
    """
    d = int(rng.integers(2, 9))
    pre = _ids("<bos>", "the", "war", "lasted", "from", "year", "17")
    post = _ids("to", "year", "17")
    clean = pre + [TOK[str(d)]] + post
    corrupt = pre + [TOK["0"]] + post
    pos = len(clean) - 1
    greater = [TOK[str(k)] for k in range(d + 1, 10)]
    lesseq = [TOK[str(k)] for k in range(0, d + 1)]
    ans = [(t, 1.0 / len(greater)) for t in greater]
    dis = [(t, 1.0 / len(lesseq)) for t in lesseq]
    label = int(rng.choice(greater))
    return Example(clean, corrupt, pos, ans, dis, label).padded()


def gen_docstring(rng: np.random.Generator) -> Example:
    """def <F> ( <A1> , <A2> , <A3> ) : param <A1> : param <A2> : param -> <A3>.

    Corruption re-samples the three signature arguments (keeping the
    docstring part intact), so the answer can no longer be read off the
    signature.
    """
    f = TOK[_FUNCS[int(rng.integers(len(_FUNCS)))]]
    a1, a2, a3, b1, b2, b3 = rng.choice(len(_ARGS), size=6, replace=False)
    A = [TOK[_ARGS[i]] for i in (a1, a2, a3)]
    B = [TOK[_ARGS[i]] for i in (b1, b2, b3)]

    def stub(args):
        return (
            _ids("<bos>", "def") + [f] + _ids("(") + [args[0]] + _ids(",")
            + [args[1]] + _ids(",") + [args[2]] + _ids(")", ":")
            + _ids("param") + [A[0]] + _ids(":", "param") + [A[1]]
            + _ids(":", "param")
        )

    clean, corrupt = stub(A), stub(B)
    pos = len(clean) - 1
    return Example(clean, corrupt, pos, [(A[2], 1.0)], [(A[0], 1.0)], A[2]).padded()


GENERATORS: dict[str, Callable[[np.random.Generator], Example]] = {
    "ioi": gen_ioi,
    "greater_than": gen_greater_than,
    "docstring": gen_docstring,
}
TASKS = list(GENERATORS)


def make_dataset(task: str, n: int, seed: int) -> list[Example]:
    rng = np.random.default_rng(seed)
    return [GENERATORS[task](rng) for _ in range(n)]


def onehot(tokens: list[int], vocab: int = VOCAB_SIZE) -> np.ndarray:
    out = np.zeros((len(tokens), vocab), dtype=np.float32)
    out[np.arange(len(tokens)), tokens] = 1.0
    return out


def dense(dist: list[tuple[int, float]], vocab: int = VOCAB_SIZE) -> np.ndarray:
    out = np.zeros((vocab,), dtype=np.float32)
    for tok, w in dist:
        out[tok] = w
    return out


def batch_arrays(examples: list[Example]):
    """Stack a dataset into the dense batched arrays the HLO inputs expect:
    clean/corrupt one-hots [B,S,V], position one-hots [B,S], ans/dis [B,V]."""
    B = len(examples)
    clean = np.stack([onehot(e.clean) for e in examples])
    corrupt = np.stack([onehot(e.corrupt) for e in examples])
    pos = np.zeros((B, SEQ_LEN), dtype=np.float32)
    for i, e in enumerate(examples):
        pos[i, e.pos] = 1.0
    ans = np.stack([dense(e.ans) for e in examples])
    dis = np.stack([dense(e.dis) for e in examples])
    labels = np.array([e.label for e in examples], dtype=np.int32)
    return clean, corrupt, pos, ans, dis, labels
