"""AOT exporter: train the tiny models and lower every computation the Rust
coordinator needs to HLO **text**.

Interchange is HLO text, NOT ``lowered.compile().serialize()`` — jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per model ``m`` this writes (under ``artifacts/<m>/``):

  embed.hlo.txt            (onehot [B,S,V], wte, wpe)            -> [B,S,D]
  attn_layer.hlo.txt       (qin,kin,vin [B,H,S,D], 8 weight
                            tensors, qp [H,3])                   -> [B,H,S,D]
  mlp_layer.hlo.txt        (xin [B,S,D], 5 weight tensors, qp3)  -> [B,S,D]
  unembed.hlo.txt          (xin, lnf_g, wu)                      -> [B,S,V]
  grads.hlo.txt            metric + node caches + dL/d(channel input)
                           as a function of eps offsets (EAP / HISP)
  gate_grads.hlo.txt       metric + dL/dgates under clean<->corrupt node
                           interpolation (SP)                    [base models]
  edge_mask_grads.hlo.txt  metric + dL/dmask for per-edge clean<->corrupt
                           mixing (Edge Pruning)                 [base models]
  weights.bin              flat little-endian f32 in param_spec order
  manifest.json            config, param layout, artifact list, train accs

plus, once, at ``artifacts/``:

  vocab.json               vocabulary + token groups (names/digits/args/...)
                           so the Rust task generators mirror python's
  datasets/<task>.json     seeded evaluation datasets (clean/corrupt pairs)

All per-layer HLOs take weights as *runtime inputs*: this is what lets the
Rust side own precision residency (FP32 master vs FP8-resident copies) and
charge the simulated PCIe transfers per edge evaluation — the heart of
PAHQ's scheduler. One attention executable serves all layers of a model
(shapes are layer-invariant).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import tasks
from .model import (
    CONFIGS,
    ModelConfig,
    attn_layer,
    embed,
    flatten_params,
    forward_edge_masked,
    forward_with_eps,
    forward_with_gates,
    get_config,
    combined_metric,
    mlp_layer,
    param_spec,
    unembed,
    zero_eps,
)
from .train import train_model

BASE_MODELS = ["redwood2l-sim", "attn4l-sim", "gpt2s-sim"]
SCALE_MODELS = ["gpt2m-sim", "gpt2l-sim", "gpt2xl-sim"]
EVAL_SEED = 777
# bump to invalidate the trained-weight cache when task data changes
DATA_VERSION = 2


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Per-layer inference artifacts


def export_layers(cfg: ModelConfig, outdir: str) -> None:
    B, H, S, D = cfg.batch, cfg.n_head, cfg.seq_len, cfg.d_model
    K, F, V = cfg.d_head, cfg.d_mlp, cfg.vocab

    def wfn(onehot, wte, wpe):
        return (embed(onehot, wte, wpe),)

    _write(outdir, "embed.hlo.txt", lower(wfn, f32(B, S, V), f32(V, D), f32(S, D)))

    # Channel inputs and per-head outputs travel as [H, B, S, D]: head-major
    # layout keeps every head's [B,S,D] block contiguous, which is what the
    # Rust residual-assembly hot path memcpys into/out of. The swap to the
    # kernel's [B,H,S,D] layout fuses inside XLA.
    #
    # Two variants are exported: the Pallas-kernel build (default runtime
    # path, the paper's L1 contribution) and a pure-jnp reference build.
    # They are value-identical (rust/src/patching tests assert it); the
    # reference build exists because interpret-mode Pallas lowers to an
    # XLA while-loop that costs ~8x on *CPU* PJRT — sweep-heavy harness
    # runs select it with PAHQ_ATTN=ref. On a real TPU the Pallas build is
    # the fast one; CPU interpret timing says nothing about TPU (DESIGN.md
    # section 2).
    def make_afn(use_pallas):
        def afn(qin, kin, vin, ln_g, wq, bq, wk, bk, wv, bv, wo, qp):
            t = lambda x: jnp.swapaxes(x, 0, 1)
            out = attn_layer(t(qin), t(kin), t(vin), ln_g, wq, bq, wk, bk,
                             wv, bv, wo, qp, use_pallas=use_pallas)
            return (jnp.swapaxes(out, 0, 1),)
        return afn

    x4 = f32(H, B, S, D)
    attn_specs = (x4, x4, x4, f32(D), f32(H, D, K), f32(H, K), f32(H, D, K),
                  f32(H, K), f32(H, D, K), f32(H, K), f32(H, K, D), f32(H, 3))
    _write(outdir, "attn_layer.hlo.txt", lower(make_afn(True), *attn_specs))
    _write(outdir, "attn_layer_ref.hlo.txt", lower(make_afn(False), *attn_specs))

    if cfg.has_mlp:
        def mfn(xin, ln2_g, w1, b1, w2, b2, qp3):
            return (mlp_layer(xin, ln2_g, w1, b1, w2, b2, qp3),)

        _write(
            outdir,
            "mlp_layer.hlo.txt",
            lower(mfn, f32(B, S, D), f32(D), f32(D, F), f32(F), f32(F, D),
                  f32(D), f32(3)),
        )

    def ufn(xin, lnf_g, wu):
        return (unembed(xin, lnf_g, wu),)

    _write(outdir, "unembed.hlo.txt", lower(ufn, f32(B, S, D), f32(D), f32(D, V)))


# ---------------------------------------------------------------------------
# Gradient artifacts (baselines)


def _weight_specs(cfg: ModelConfig):
    return [f32(*shape) for _, shape in param_spec(cfg)]


def _params_from_list(cfg: ModelConfig, plist):
    return {name: p for (name, _), p in zip(param_spec(cfg), plist)}


def export_grads(cfg: ModelConfig, outdir: str) -> None:
    """EAP/HISP artifact. Inputs: onehot, pos, ans, dis, ref_probs, sel,
    then all weights (param_spec order). Outputs (tuple, in order):
      metric, embed [B,S,D], attn [L,H,B,S,D], (mlp [L,B,S,D]),
      gq, gk, gv, ghout [L,H,B,S,D], (gmlp [L,B,S,D]), gfinal [B,S,D].
    Per-head tensors are head-major ([L,H,B,S,D]) so each node's [B,S,D]
    block is contiguous for the Rust side. Gradients are w.r.t. each
    channel's *input offset* evaluated at the unmodified forward — exactly
    EAP's dL/d(edge destination input)."""
    B, S, V = cfg.batch, cfg.seq_len, cfg.vocab

    def gfn(onehot, pos, ans, dis, ref_probs, sel, *plist):
        params = _params_from_list(cfg, plist)

        def f(eps):
            return forward_with_eps(cfg, params, onehot, pos, ans, dis,
                                    ref_probs, sel, eps)

        (metric, caches), grads = jax.value_and_grad(f, has_aux=True)(zero_eps(cfg))
        hm = lambda x: jnp.moveaxis(x, 2, 1)  # [L,B,H,S,D] -> [L,H,B,S,D]
        attn = hm(jnp.stack([caches[f"attn{l}"] for l in range(cfg.n_layer)]))
        outs = [metric, caches["embed"], attn]
        if cfg.has_mlp:
            outs.append(jnp.stack([caches[f"mlp{l}"] for l in range(cfg.n_layer)]))
        outs += [hm(grads["eps_q"]), hm(grads["eps_k"]), hm(grads["eps_v"]),
                 hm(grads["eps_hout"])]
        if cfg.has_mlp:
            outs.append(grads["eps_mlp"])
        outs.append(grads["eps_final"])
        return tuple(outs)

    specs = [f32(B, S, V), f32(B, S), f32(B, V), f32(B, V), f32(B, V), f32()]
    _write(outdir, "grads.hlo.txt", lower(gfn, *specs, *_weight_specs(cfg)))


def export_gate_grads(cfg: ModelConfig, outdir: str) -> None:
    """SP artifact. Extra inputs: gates [N], corrupt attn cache
    [L,H,B,S,D] head-major (+ corrupt mlp cache [L,B,S,D]). Outputs:
    (metric, dgates)."""
    B, H, S, D, V = cfg.batch, cfg.n_head, cfg.seq_len, cfg.d_model, cfg.vocab
    L = cfg.n_layer
    n_nodes = cfg.n_nodes

    def gfn(onehot, pos, ans, dis, ref_probs, sel, gates, attn_c, mlp_c, *plist):
        params = _params_from_list(cfg, plist)
        attn_c = jnp.moveaxis(attn_c, 1, 2)  # [L,H,B,S,D] -> [L,B,H,S,D]
        caches = {f"attn{l}": attn_c[l] for l in range(L)}
        for l in range(L):
            caches[f"mlp{l}"] = mlp_c[l]

        def f(g):
            return forward_with_gates(cfg, params, onehot, pos, ans, dis,
                                      ref_probs, sel, g, corrupt_caches=caches)

        metric, dg = jax.value_and_grad(f)(gates)
        return metric, dg

    specs = [
        f32(B, S, V), f32(B, S), f32(B, V), f32(B, V), f32(B, V), f32(),
        f32(n_nodes), f32(L, H, B, S, D),
        f32(L, B, S, D) if cfg.has_mlp else f32(L, 1, 1, 1),
    ]
    if not cfg.has_mlp:
        # keep the input arity fixed; a dummy is cheaper than two signatures
        def gfn_nomlp(onehot, pos, ans, dis, ref_probs, sel, gates, attn_c,
                      _dummy, *plist):
            params = _params_from_list(cfg, plist)
            attn_c = jnp.moveaxis(attn_c, 1, 2)
            caches = {f"attn{l}": attn_c[l] for l in range(L)}

            def f(g):
                return forward_with_gates(cfg, params, onehot, pos, ans, dis,
                                          ref_probs, sel, g, corrupt_caches=caches)

            metric, dg = jax.value_and_grad(f)(gates)
            # keep the dummy alive: XLA would otherwise DCE the parameter
            # and shift the executable's input arity
            metric = metric + 0.0 * jnp.sum(_dummy)
            return metric, dg

        gfn = gfn_nomlp
    _write(outdir, "gate_grads.hlo.txt", lower(gfn, *specs, *_weight_specs(cfg)))


def export_edge_mask_grads(cfg: ModelConfig, outdir: str) -> None:
    """Edge-Pruning artifact. Inputs: onehot_clean, pos, ans, dis,
    ref_probs, sel, corrupt node outputs [N,B,S,D], masks (mq/mk/mv
    [L,H,N], mm [L,N], mf [N]), weights. Outputs:
    (metric, dmq, dmk, dmv, dmm, dmf)."""
    B, H, S, D, V = cfg.batch, cfg.n_head, cfg.seq_len, cfg.d_model, cfg.vocab
    L, N = cfg.n_layer, cfg.n_nodes

    def gfn(onehot, pos, ans, dis, ref_probs, sel, corrupt_nodes,
            mq, mk, mv, mm, mf, *plist):
        params = _params_from_list(cfg, plist)

        def f(masks):
            logits = forward_edge_masked(cfg, params, onehot, masks,
                                         corrupt_nodes)
            m = combined_metric(logits, pos, ans, dis, ref_probs, sel)
            if not cfg.has_mlp:
                # attn-only models never read the MLP masks — keep the
                # parameter alive or XLA DCEs it and shifts input arity
                m = m + 0.0 * jnp.sum(masks["mm"])
            return m

        masks = {"mq": mq, "mk": mk, "mv": mv, "mm": mm, "mf": mf}
        metric, dm = jax.value_and_grad(f)(masks)
        return metric, dm["mq"], dm["mk"], dm["mv"], dm["mm"], dm["mf"]

    specs = [
        f32(B, S, V), f32(B, S), f32(B, V), f32(B, V), f32(B, V), f32(),
        f32(N, B, S, D), f32(L, H, N), f32(L, H, N), f32(L, H, N),
        f32(L, N), f32(N),
    ]
    _write(outdir, "edge_mask_grads.hlo.txt", lower(gfn, *specs, *_weight_specs(cfg)))


# ---------------------------------------------------------------------------
# Datasets / vocab / manifest


def export_fq_vectors(root: str, n: int = 8192) -> None:
    """Bit-exactness vectors for the Rust quant codecs: random f32 samples
    (log-uniform magnitudes spanning subnormal..overflow per format) and
    their fake-quantized values under each preset. rust/src/quant tests
    assert exact equality on every sample."""
    from . import quantize

    rng = np.random.default_rng(12345)
    mag = np.exp2(rng.uniform(-14.0, 14.0, size=n)).astype(np.float32)
    sign = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    x = (mag * sign).astype(np.float32)
    x[:16] = [0.0, -0.0, 1.0, -1.0, 448.0, 449.0, 0.001, -0.001,
              6.5, 7.5, 2.5, 3.5, 0.0625, 0.03125, 1e-8, 65520.0]
    out = {"x": x.tolist()}
    for name in ("fp8_e4m3", "fp8_e5m2", "fp4_e2m1", "bf16", "fp16"):
        y = np.asarray(quantize.fake_quant_qp(jnp.asarray(x), quantize.qp_array(name)))
        out[name] = y.astype(np.float32).tolist()
    os.makedirs(os.path.join(root, "testvectors"), exist_ok=True)
    with open(os.path.join(root, "testvectors", "fq_cases.json"), "w") as f:
        json.dump(out, f)


def export_vocab(root: str) -> None:
    data = {
        "vocab": tasks.VOCAB,
        "pad": tasks.PAD,
        "bos": tasks.BOS,
        "seq_len": tasks.SEQ_LEN,
        "groups": {
            "names": [tasks.TOK[n] for n in tasks._NAMES],
            "args": [tasks.TOK[a] for a in tasks._ARGS],
            "funcs": [tasks.TOK[f] for f in tasks._FUNCS],
            "digits": [tasks.TOK[d] for d in tasks._DIGITS],
            "words": {w: tasks.TOK[w] for w in tasks._WORDS},
        },
    }
    with open(os.path.join(root, "vocab.json"), "w") as f:
        json.dump(data, f)


def export_datasets(root: str, n: int = 256) -> None:
    os.makedirs(os.path.join(root, "datasets"), exist_ok=True)
    for task in tasks.TASKS:
        exs = tasks.make_dataset(task, n, EVAL_SEED)
        data = {
            "task": task,
            "seq_len": tasks.SEQ_LEN,
            "examples": [
                {
                    "clean": e.clean,
                    "corrupt": e.corrupt,
                    "pos": e.pos,
                    "ans": [[t, w] for t, w in e.ans],
                    "dis": [[t, w] for t, w in e.dis],
                    "label": e.label,
                }
                for e in exs
            ],
        }
        with open(os.path.join(root, "datasets", f"{task}.json"), "w") as f:
            json.dump(data, f)


def export_expected(cfg: ModelConfig, params, outdir: str) -> None:
    """Golden outputs for the Rust integration tests: FP32 clean and corrupt
    logits of the first ``cfg.batch`` eval examples of each task, computed
    through the pure-jnp reference path. The Rust patched-forward engine
    (PJRT-chained per-layer HLOs + Rust residual assembly) must reproduce
    these to ~1e-4 — this pins the whole L1+L2+runtime+L3 composition."""
    from .model import forward_full

    exp_dir = os.path.join(outdir, "expected")
    os.makedirs(exp_dir, exist_ok=True)
    for task in tasks.TASKS:
        exs = tasks.make_dataset(task, cfg.batch, EVAL_SEED)
        clean, corrupt, _, _, _, _ = tasks.batch_arrays(exs)
        for tag, oh in (("clean", clean), ("corrupt", corrupt)):
            logits = forward_full(cfg, params, jnp.asarray(oh))
            np.asarray(logits, np.float32).astype("<f4").tofile(
                os.path.join(exp_dir, f"{task}_{tag}_logits.bin")
            )


def export_manifest(cfg: ModelConfig, outdir: str, accs: dict, artifacts: list[str],
                    train_meta: dict) -> None:
    spec = []
    off = 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        spec.append({"name": name, "shape": list(shape), "offset": off, "size": n})
        off += n
    manifest = {
        "name": cfg.name,
        "n_layer": cfg.n_layer,
        "n_head": cfg.n_head,
        "d_model": cfg.d_model,
        "d_head": cfg.d_head,
        "d_mlp": cfg.d_mlp,
        "seq_len": cfg.seq_len,
        "vocab": cfg.vocab,
        "batch": cfg.batch,
        "n_params": off,
        "params": spec,
        "artifacts": artifacts,
        "train_accuracy": accs,
        "train": train_meta,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def _write(outdir: str, name: str, text: str) -> None:
    path = os.path.join(outdir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1e6:.2f} MB)")


# ---------------------------------------------------------------------------
# Driver


def source_fingerprint() -> str:
    """Hash of the compile-path sources — artifacts rebuild when these
    change (consumed by the Makefile via the stamp file)."""
    h = hashlib.sha256()
    here = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(here)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()[:16]


def build_model(name: str, root: str, quick: bool) -> None:
    cfg = get_config(name, tasks.VOCAB_SIZE)
    outdir = os.path.join(root, cfg.name)
    os.makedirs(outdir, exist_ok=True)

    is_scale = name in SCALE_MODELS
    task_names = ["ioi"] if is_scale else tasks.TASKS
    steps = 300 if quick else (700 if is_scale else 2400)

    # Weight cache: retraining is the expensive part of `make artifacts`;
    # if a previous run trained this exact (model, steps, tasks) config,
    # reuse its weights.bin and only re-lower the HLOs.
    from .model import unflatten_params

    wpath = os.path.join(outdir, "weights.bin")
    mpath = os.path.join(outdir, "manifest.json")
    params = accs = None
    if os.path.exists(wpath) and os.path.exists(mpath):
        try:
            with open(mpath) as f:
                old = json.load(f)
            if old.get("train") == {"steps": steps, "tasks": task_names,
                                    "data_version": DATA_VERSION} and \
               os.path.getsize(wpath) == old["n_params"] * 4:
                flat = np.fromfile(wpath, dtype="<f4")
                params = unflatten_params(cfg, flat)
                accs = old["train_accuracy"]
                print(f"[{cfg.name}] reusing cached weights "
                      f"(accuracy {accs})")
        except Exception as e:  # fall through to retrain
            print(f"[{cfg.name}] weight cache miss: {e}")

    if params is None:
        print(f"[{cfg.name}] training on {task_names} for {steps} steps")
        t0 = time.time()
        # stable per-model seed (python's hash() is salted per process)
        seed = int(hashlib.sha256(name.encode()).hexdigest()[:8], 16)
        params, accs = train_model(cfg, task_names, steps=steps,
                                   batch=48, seed=seed)
        print(f"[{cfg.name}] accuracy: {accs} ({time.time() - t0:.0f}s)")
        flat = flatten_params(cfg, params)
        flat.astype("<f4").tofile(wpath)

    artifacts = ["embed.hlo.txt", "attn_layer.hlo.txt", "unembed.hlo.txt",
                 "grads.hlo.txt"]
    export_layers(cfg, outdir)
    export_grads(cfg, outdir)
    export_expected(cfg, params, outdir)
    if cfg.has_mlp:
        artifacts.insert(2, "mlp_layer.hlo.txt")
    if not is_scale:
        export_gate_grads(cfg, outdir)
        export_edge_mask_grads(cfg, outdir)
        artifacts += ["gate_grads.hlo.txt", "edge_mask_grads.hlo.txt"]
    export_manifest(cfg, outdir, accs, artifacts,
                    {"steps": steps, "tasks": task_names,
                     "data_version": DATA_VERSION})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="artifacts root (default ../artifacts)")
    ap.add_argument("--models", default=None,
                    help="comma-separated subset (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="short training runs (CI/tests)")
    args = ap.parse_args()

    root = args.out or os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    root = os.path.abspath(root)
    os.makedirs(root, exist_ok=True)

    names = args.models.split(",") if args.models else BASE_MODELS + SCALE_MODELS
    for name in names:
        assert name in CONFIGS, f"unknown model {name}"

    # stale derived caches (ground-truth circuits depend on the weights)
    import shutil

    shutil.rmtree(os.path.join(root, "groundtruth"), ignore_errors=True)

    export_vocab(root)
    export_datasets(root)
    export_fq_vectors(root)
    for name in names:
        build_model(name, root, args.quick)

    with open(os.path.join(root, "stamp.json"), "w") as f:
        json.dump({"fingerprint": source_fingerprint(), "models": names}, f)
    print("artifacts complete.")


if __name__ == "__main__":
    main()
