"""L2: the graph-decomposed transformer in JAX.

Circuit discovery views a transformer as a DAG whose nodes are attention
heads and MLP blocks writing into a shared residual stream, and whose edges
are (source node output -> destination node input-channel) contributions.
Everything in this module is written in that decomposed form:

- each attention head h in layer l reads three *channels* (Q, K, V), each of
  which is an independently-assembled residual sum — this is what makes
  edge-level activation patching expressible;
- head outputs are kept per-head (z_h @ W_O[h]) and never pre-summed, so
  the Rust coordinator can cache node values and assemble arbitrary hybrid
  inputs;
- per-head quant parameter rows (mbits, emin, maxv) thread through every
  attention computation — PAHQ's precision allocation P_t (paper Eq. 3) is
  a runtime input, not a compile-time constant.

Two families of entry points:

1. Per-layer inference functions (``embed``/``attn_layer``/``mlp_layer``/
   ``unembed``) — AOT-lowered to HLO text by ``aot.py`` and chained at
   runtime by the Rust patched-forward engine. These call the Pallas
   kernels (L1).
2. Whole-graph differentiable forwards (``forward_full``,
   ``forward_with_eps``, ``forward_with_gates``, ``forward_edge_masked``) —
   used for build-time training and for the gradient artifacts powering the
   EAP / HISP / SP / Edge-Pruning baselines. These use the pure-jnp oracle
   path (Pallas is not differentiable).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels.attn_core import attn_core_pallas
from .kernels.mixed_attn import project_heads_pallas
from .quantize import FP32, fake_quant_qp

# ---------------------------------------------------------------------------
# Configuration


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Shape family of a model. ``d_mlp == 0`` means attention-only."""

    name: str
    n_layer: int
    n_head: int
    d_model: int
    d_head: int
    d_mlp: int
    seq_len: int
    vocab: int
    batch: int  # evaluation batch baked into the AOT shapes

    @property
    def has_mlp(self) -> bool:
        return self.d_mlp > 0

    @property
    def n_nodes(self) -> int:
        """embed + heads (layer-major) + one MLP per layer (if any)."""
        return 1 + self.n_layer * self.n_head + (self.n_layer if self.has_mlp else 0)


# Layer parameter names, in the order they appear as HLO inputs and in the
# flat weights.bin blob. Keep in sync with rust/src/model/weights.rs.
ATTN_PARAMS = ["ln1_g", "wq", "bq", "wk", "bk", "wv", "bv", "wo"]
MLP_PARAMS = ["ln2_g", "w1", "b1", "w2", "b2"]


def param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list defining the weights.bin layout."""
    H, D, K, F = cfg.n_head, cfg.d_model, cfg.d_head, cfg.d_mlp
    spec: list[tuple[str, tuple[int, ...]]] = [
        ("wte", (cfg.vocab, D)),
        ("wpe", (cfg.seq_len, D)),
    ]
    for l in range(cfg.n_layer):
        spec += [
            (f"l{l}.ln1_g", (D,)),
            (f"l{l}.wq", (H, D, K)),
            (f"l{l}.bq", (H, K)),
            (f"l{l}.wk", (H, D, K)),
            (f"l{l}.bk", (H, K)),
            (f"l{l}.wv", (H, D, K)),
            (f"l{l}.bv", (H, K)),
            (f"l{l}.wo", (H, K, D)),
        ]
        if cfg.has_mlp:
            spec += [
                (f"l{l}.ln2_g", (D,)),
                (f"l{l}.w1", (D, F)),
                (f"l{l}.b1", (F,)),
                (f"l{l}.w2", (F, D)),
                (f"l{l}.b2", (D,)),
            ]
    spec += [("lnf_g", (D,)), ("wu", (D, cfg.vocab))]
    return spec


def init_params(cfg: ModelConfig, seed: int) -> dict[str, jnp.ndarray]:
    """Small-scale GPT-2-style init over the param spec."""
    rng = np.random.default_rng(seed)
    params: dict[str, jnp.ndarray] = {}
    for name, shape in param_spec(cfg):
        base = name.split(".")[-1]
        if base.startswith("ln"):
            arr = np.ones(shape, np.float32)
        elif base.startswith("b"):
            arr = np.zeros(shape, np.float32)
        else:
            scale = 0.04 if base in ("wo", "w2") else 0.08
            arr = rng.normal(0.0, scale, size=shape).astype(np.float32)
        params[name] = jnp.asarray(arr)
    return params


def flatten_params(cfg: ModelConfig, params: dict[str, jnp.ndarray]) -> np.ndarray:
    return np.concatenate(
        [np.asarray(params[n], np.float32).reshape(-1) for n, _ in param_spec(cfg)]
    )


def unflatten_params(cfg: ModelConfig, flat: np.ndarray) -> dict[str, jnp.ndarray]:
    out, off = {}, 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        out[name] = jnp.asarray(flat[off : off + n].reshape(shape))
        off += n
    assert off == flat.size
    return out


def fp32_qp(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.tile(jnp.asarray(FP32, jnp.float32)[None], (cfg.n_head, 1))


# ---------------------------------------------------------------------------
# Per-layer inference functions (AOT entry points)


def embed(onehot, wte, wpe):
    """onehot [B,S,V] @ wte [V,D] + wpe [S,D] -> [B,S,D].

    Tokens travel as one-hot f32 so the artifact needs no integer gather
    (keeps the HLO text within what xla_extension 0.5.1 parses trivially,
    and V is tiny here).
    """
    return jnp.einsum("bsv,vd->bsd", onehot, wte) + wpe[None]


def attn_layer(qin, kin, vin, ln_g, wq, bq, wk, bk, wv, bv, wo, qp, use_pallas=True):
    """Per-head attention layer over pre-assembled channel inputs.

    qin/kin/vin [B,H,S,D]: each head's Q/K/V-channel residual input, built
    by the caller (Rust at runtime; ``forward_full`` at train time).
    Returns per-head residual contributions [B,H,S,D] (z_h @ W_O[h]) — NOT
    summed, so every head remains an addressable graph node.
    """
    proj = project_heads_pallas if use_pallas else ref.project_heads
    core = attn_core_pallas if use_pallas else ref.attn_core
    q = proj(qin, ln_g, wq, bq, qp)
    k = proj(kin, ln_g, wk, bk, qp)
    v = proj(vin, ln_g, wv, bv, qp)
    z = core(q, k, v, qp)
    return jnp.einsum("bhsk,hkd->bhsd", z, wo)


def mlp_layer(xin, ln2_g, w1, b1, w2, b2, qp3):
    """MLP node: xin [B,S,D] -> [B,S,D]; qp3 is a single (3,) quant row
    (the paper runs non-attention components at bf16)."""
    xn = ref.rmsnorm(xin, ln2_g)
    h = fake_quant_qp(jnp.einsum("bsd,df->bsf", xn, w1) + b1, qp3)
    h = jax.nn.gelu(h)
    y = fake_quant_qp(jnp.einsum("bsf,fd->bsd", h, w2) + b2, qp3)
    return y


def unembed(xin, lnf_g, wu):
    """Final node: xin [B,S,D] -> logits [B,S,V]."""
    return jnp.einsum("bsd,dv->bsv", ref.rmsnorm(xin, lnf_g), wu)


# ---------------------------------------------------------------------------
# Whole-graph differentiable forwards (training + gradient artifacts)


def node_index(cfg: ModelConfig):
    """Node ordering shared with Rust: 0 = embed; heads layer-major
    (1 + l*H + h); MLPs after all heads (1 + L*H + l)."""
    names = ["embed"]
    for l in range(cfg.n_layer):
        for h in range(cfg.n_head):
            names.append(f"a{l}.h{h}")
    if cfg.has_mlp:
        for l in range(cfg.n_layer):
            names.append(f"m{l}")
    return names


def _layer_w(params, l, names):
    return [params[f"l{l}.{n}"] for n in names]


def forward_full(cfg, params, onehot, eps=None, gates=None, collect=False):
    """Standard decomposed forward (all edges present).

    eps   : optional dict of per-channel input offsets — ``jax.grad`` w.r.t.
            these yields dL/d(channel input), the quantity EAP needs.
            Keys: eps_q/eps_k/eps_v [L,B,H,S,D], eps_mlp [L,B,S,D],
            eps_final [B,S,D], eps_hout [L,B,H,S,D].
    gates : optional [n_nodes] multiplicative node gates (SP / HISP).
    collect: also return every node's output tensor.

    Returns logits [B,S,V] (and caches if ``collect``).
    """
    B = onehot.shape[0]
    qp = fp32_qp(cfg)
    resid = embed(onehot, params["wte"], params["wpe"])
    caches = {"embed": resid}
    if gates is not None:
        resid = resid * 1.0  # embed is not gated (it anchors the stream)
    for l in range(cfg.n_layer):
        x = resid[:, None].repeat(cfg.n_head, axis=1)  # [B,H,S,D]
        xq, xk, xv = x, x, x
        if eps is not None:
            xq = xq + eps["eps_q"][l]
            xk = xk + eps["eps_k"][l]
            xv = xv + eps["eps_v"][l]
        houts = attn_layer(xq, xk, xv, *_layer_w(params, l, ATTN_PARAMS), qp,
                           use_pallas=False)
        if eps is not None:
            houts = houts + eps["eps_hout"][l]
        if gates is not None:
            g = gates[1 + l * cfg.n_head : 1 + (l + 1) * cfg.n_head]
            houts = houts * g[None, :, None, None]
        caches[f"attn{l}"] = houts
        resid = resid + jnp.sum(houts, axis=1)
        if cfg.has_mlp:
            xm = resid
            if eps is not None:
                xm = xm + eps["eps_mlp"][l]
            mout = mlp_layer(xm, *_layer_w(params, l, MLP_PARAMS),
                             jnp.asarray(FP32, jnp.float32))
            if gates is not None:
                g = gates[1 + cfg.n_layer * cfg.n_head + l]
                mout = mout * g
            caches[f"mlp{l}"] = mout
            resid = resid + mout
    if eps is not None:
        resid = resid + eps["eps_final"]
    logits = unembed(resid, params["lnf_g"], params["wu"])
    return (logits, caches) if collect else logits


def zero_eps(cfg: ModelConfig):
    L, B, H, S, D = cfg.n_layer, cfg.batch, cfg.n_head, cfg.seq_len, cfg.d_model
    z4 = jnp.zeros((L, B, H, S, D), jnp.float32)
    z3 = jnp.zeros((L, B, S, D), jnp.float32)
    return {
        "eps_q": z4, "eps_k": z4, "eps_v": z4, "eps_hout": z4,
        "eps_mlp": z3, "eps_final": jnp.zeros((B, S, D), jnp.float32),
    }


# --- metrics on logits ------------------------------------------------------


def metric_logit_diff(logits, pos, ans, dis):
    """Mean over batch of <logits[pos], ans> - <logits[pos], dis>.

    pos [B,S] one-hot answer positions; ans/dis [B,V] (possibly soft)
    answer/distractor distributions. This is the paper's "task metric"
    (logit difference; mean-logit gap for Greater-Than's digit sets).
    """
    at_pos = jnp.einsum("bs,bsv->bv", pos, logits)
    return jnp.mean(jnp.sum(at_pos * (ans - dis), axis=-1))


def metric_kl(logits, pos, ref_probs):
    """Mean KL(ref_probs || softmax(logits[pos])) — ACDC's KL metric,
    measured against the clean run's answer-position distribution."""
    at_pos = jnp.einsum("bs,bsv->bv", pos, logits)
    logp = jax.nn.log_softmax(at_pos, axis=-1)
    ref = jnp.clip(ref_probs, 1e-9, 1.0)
    return jnp.mean(jnp.sum(ref * (jnp.log(ref) - logp), axis=-1))


def combined_metric(logits, pos, ans, dis, ref_probs, sel):
    """sel=1 -> logit-diff metric; sel=0 -> KL metric. ``sel`` is a runtime
    scalar input so one gradient artifact serves both metric columns."""
    return sel * metric_logit_diff(logits, pos, ans, dis) + (1.0 - sel) * metric_kl(
        logits, pos, ref_probs
    )


# --- gradient-artifact forwards --------------------------------------------


def forward_with_eps(cfg, params, onehot, pos, ans, dis, ref_probs, sel, eps):
    """Scalar metric + node caches as a function of channel offsets ``eps``.

    ``aot.py`` lowers ``jax.value_and_grad`` of this w.r.t. ``eps`` — the
    resulting artifact returns, in one execution, every node output and
    every dL/d(channel input), which is all EAP and HISP need.
    """
    logits, caches = forward_full(cfg, params, onehot, eps=eps, collect=True)
    return combined_metric(logits, pos, ans, dis, ref_probs, sel), caches


def forward_with_gates(cfg, params, onehot, pos, ans, dis, ref_probs, sel, gates,
                       corrupt_caches=None):
    """Metric as a function of node gates (SP).

    With ``corrupt_caches`` (node outputs from a corrupted forward), gate
    g interpolates node outputs between clean (g=1) and corrupted (g=0)
    computation — subnetwork probing's mask semantics. Implemented by
    re-running the decomposed forward with interpolated node outputs.
    """
    qp = fp32_qp(cfg)
    resid = embed(onehot, params["wte"], params["wpe"])
    for l in range(cfg.n_layer):
        x = resid[:, None].repeat(cfg.n_head, axis=1)
        houts = attn_layer(x, x, x, *_layer_w(params, l, ATTN_PARAMS), qp,
                           use_pallas=False)
        g = gates[1 + l * cfg.n_head : 1 + (l + 1) * cfg.n_head][None, :, None, None]
        if corrupt_caches is not None:
            houts = g * houts + (1.0 - g) * corrupt_caches[f"attn{l}"]
        else:
            houts = g * houts
        resid = resid + jnp.sum(houts, axis=1)
        if cfg.has_mlp:
            mout = mlp_layer(resid, *_layer_w(params, l, MLP_PARAMS),
                             jnp.asarray(FP32, jnp.float32))
            gm = gates[1 + cfg.n_layer * cfg.n_head + l]
            if corrupt_caches is not None:
                mout = gm * mout + (1.0 - gm) * corrupt_caches[f"mlp{l}"]
            else:
                mout = gm * mout
            resid = resid + mout
    logits = unembed(resid, params["lnf_g"], params["wu"])
    return combined_metric(logits, pos, ans, dis, ref_probs, sel)


def forward_edge_masked(cfg, params, onehot_clean, masks, corrupt_nodes):
    """Edge-Pruning forward: every (source node -> destination channel) edge
    carries a mask m in [0,1]; the channel input is
    sum_src m * clean_contribution + (1 - m) * corrupt_contribution.

    corrupt_nodes: [N, B, S, D] node outputs from the corrupted run
    (embed + heads layer-major + mlps — Rust supplies its caches).
    masks: dict with mq/mk/mv [L, H, N], mm [L, N], mf [N]. Entries for
    causally-invalid sources are ignored (their clean contribution is used,
    and Rust keeps them fixed at 1).

    Returns logits [B,S,V]; aot.py lowers value_and_grad of a metric of
    this w.r.t. ``masks``.
    """
    H = cfg.n_head
    qp = fp32_qp(cfg)

    def node_id(kind, l, h=0):
        if kind == "embed":
            return 0
        if kind == "head":
            return 1 + l * H + h
        return 1 + cfg.n_layer * H + l  # mlp

    emb = embed(onehot_clean, params["wte"], params["wpe"])
    clean_nodes = [emb]  # grows as nodes are computed (same index order)

    def channel_input(mask_row, n_valid):
        """mask_row [N]; mixes the first n_valid nodes. -> [B,S,D]"""
        acc = 0.0
        for s in range(n_valid):
            m = mask_row[s]
            acc = acc + m * clean_nodes[s] + (1.0 - m) * corrupt_nodes[s]
        return acc

    for l in range(cfg.n_layer):
        n_valid = len(clean_nodes)
        qin = jnp.stack([channel_input(masks["mq"][l, h], n_valid) for h in range(H)], 1)
        kin = jnp.stack([channel_input(masks["mk"][l, h], n_valid) for h in range(H)], 1)
        vin = jnp.stack([channel_input(masks["mv"][l, h], n_valid) for h in range(H)], 1)
        houts = attn_layer(qin, kin, vin, *_layer_w(params, l, ATTN_PARAMS), qp,
                           use_pallas=False)
        for h in range(H):
            clean_nodes.append(houts[:, h])
        if cfg.has_mlp:
            xm = channel_input(masks["mm"][l], len(clean_nodes))
            mout = mlp_layer(xm, *_layer_w(params, l, MLP_PARAMS),
                             jnp.asarray(FP32, jnp.float32))
            clean_nodes.append(mout)
    final = channel_input(masks["mf"], len(clean_nodes))
    return unembed(final, params["lnf_g"], params["wu"])


# ---------------------------------------------------------------------------
# Model zoo (shape families mirroring the paper's models; see DESIGN.md §1)

CONFIGS = {
    # paper: redwood-2l (2-layer attention-only)
    "redwood2l-sim": ModelConfig("redwood2l-sim", 2, 4, 32, 8, 0, 20, 0, 16),
    # paper: attn-4l (4-layer attention-only)
    "attn4l-sim": ModelConfig("attn4l-sim", 4, 4, 48, 12, 0, 20, 0, 16),
    # paper: gpt2-small
    "gpt2s-sim": ModelConfig("gpt2s-sim", 4, 8, 64, 8, 256, 20, 0, 16),
    # paper appendix C scale series: gpt2 medium / large / xl. Batch sizes
    # 6/5/4 mirror Tab. 7's batched edge evaluation on larger models.
    "gpt2m-sim": ModelConfig("gpt2m-sim", 6, 8, 96, 12, 384, 20, 0, 6),
    "gpt2l-sim": ModelConfig("gpt2l-sim", 8, 8, 128, 16, 512, 20, 0, 5),
    "gpt2xl-sim": ModelConfig("gpt2xl-sim", 10, 8, 160, 20, 640, 20, 0, 4),
}


def get_config(name: str, vocab: int) -> ModelConfig:
    cfg = CONFIGS[name]
    return dataclasses.replace(cfg, vocab=vocab)
