"""L2 build-time compile path: model, kernels, tasks, quantize, train, aot."""
